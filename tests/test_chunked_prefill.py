"""Chunked prefill / unified token-budget step: token-exactness vs the
monolithic prefill across budgets (including the budget < prompt <
2*budget edges), preempt-at-every-chunk resume exactness, incremental
page allocation (the graft-free admission path), and the spill-store
satellites (zstd codec, LRU eviction -> redo-from-prefill).

The hypothesis invariant (per-tick batch tokens <= budget +
n_decode_slots under random traces) lives in ``test_property.py``,
which guards the optional dependency.
"""
import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.serving.batching import Request, poisson_trace
from repro.serving.engine import (PREFILLING, ContinuousEngine,
                                  PagedSlotManager)
from repro.serving.paging import DeltaSpillStore, zstd
from repro.serving.scheduler import PreemptiveScheduler

from helpers import f32_cfg


@pytest.fixture(scope="module")
def cfg():
    return f32_cfg("smollm-360m")


@pytest.fixture(scope="module")
def params(cfg):
    return T.init_params(jax.random.PRNGKey(0), cfg, max_seq=64)


def _clone(reqs):
    return [r.clone() for r in reqs]


def _paired_tokens(res_a, res_b):
    return [(res_a[a].tokens, res_b[b].tokens)
            for a, b in zip(sorted(res_a), sorted(res_b))]


def _assert_drained(eng):
    alloc = getattr(eng.slots, "allocator", None)
    if alloc is not None:
        assert alloc.in_use == 0 and alloc.reserved == 0


# ---------------------------------------------------------------------------
# token-exactness vs monolithic prefill across budgets
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("budget", [4, 8, 16, None])
def test_chunked_matches_monolithic_budget_sweep(cfg, params, budget):
    """Every budget (None = single whole-prompt chunk) must reproduce
    the contiguous engine's monolithic-prefill token streams on a mixed
    trace.  Prompt lengths straddle every chunking edge: below the
    budget, budget < prompt < 2*budget, and several-chunk prompts."""
    trace = poisson_trace(10, rate=0.7, prompt_lens=(3, 14), max_new=(1, 10),
                         vocab_size=cfg.vocab_size, seed=11)
    mono = ContinuousEngine(cfg, params, n_slots=3, max_seq=64,
                            kv_layout="contiguous").run(_clone(trace))
    chunked = ContinuousEngine(cfg, params, n_slots=3, max_seq=64,
                               kv_layout="paged", page_size=8,
                               prefill_budget_tokens=budget).run(_clone(trace))
    assert len(mono) == len(chunked) == len(trace)
    for want, got in _paired_tokens(mono, chunked):
        np.testing.assert_array_equal(got, want)


def test_budget_lt_prompt_lt_twice_budget_edge(cfg, params):
    """The two-chunk edge: budget < prompt < 2*budget splits the prompt
    into one full chunk and one partial chunk across two ticks."""
    prompt = np.arange(1, 12, dtype=np.int32)          # 11 tokens
    mono = ContinuousEngine(cfg, params, n_slots=1, max_seq=64,
                            kv_layout="contiguous")
    want = list(mono.run([Request(prompt=prompt.copy(),
                                  max_new=6)]).values())[0].tokens
    eng = ContinuousEngine(cfg, params, n_slots=1, max_seq=64,
                           page_size=8, prefill_budget_tokens=8)
    probe = Request(prompt=prompt.copy(), max_new=6)
    eng.submit(probe)
    eng.step()                                         # chunk 1: 8 tokens
    (slot,) = eng.slots.active_slots()
    st = eng.slots.states[slot]
    assert st.phase == PREFILLING and probe.prefill_pos == 8
    assert st.emitted == []                            # no token yet
    eng.step()                                         # chunk 2: 3 tokens
    assert eng.slots.states[slot].phase != PREFILLING
    assert probe.prefill_pos == 11
    res = eng.run()
    np.testing.assert_array_equal(res[probe.rid].tokens, want)
    assert res[probe.rid].first_token_step > res[probe.rid].admitted_step
    _assert_drained(eng)


@pytest.mark.slow   # compiles chunked prefill + decode per arch
@pytest.mark.parametrize("arch", [
    "qwen3-moe-30b-a3b",    # per-chunk dynamic expert capacity
    "deepseek-v3-671b",     # MLA absorbed chunk attention
])
@pytest.mark.parametrize("budget", [4, 16])
def test_chunked_matches_monolithic_all_families(arch, budget):
    fam_cfg = f32_cfg(arch)
    fam_params = T.init_params(jax.random.PRNGKey(0), fam_cfg, max_seq=64)
    rng = np.random.default_rng(6)
    reqs = [Request(prompt=rng.integers(1, fam_cfg.vocab_size, 11)
                    .astype(np.int32), max_new=5),
            Request(prompt=rng.integers(1, fam_cfg.vocab_size, 9)
                    .astype(np.int32), max_new=7, arrival_t=2.0)]
    mono = ContinuousEngine(fam_cfg, fam_params, n_slots=2, max_seq=64,
                            kv_layout="contiguous").run(_clone(reqs))
    chunked = ContinuousEngine(
        fam_cfg, fam_params, n_slots=2, max_seq=64, kv_layout="paged",
        prefill_budget_tokens=budget).run(_clone(reqs))
    for want, got in _paired_tokens(mono, chunked):
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# graft-free admission: pages land incrementally, ticks stay bounded
# ---------------------------------------------------------------------------

def test_pages_allocated_incrementally_as_chunks_land(cfg, params):
    """Admission allocates NO pages (reservation only); each chunk draws
    exactly the pages it writes.  The old path allocated every prompt
    page up front and grafted a whole prefix cache over them."""
    eng = ContinuousEngine(cfg, params, n_slots=1, max_seq=64,
                           page_size=8, prefill_budget_tokens=8)
    probe = Request(prompt=np.arange(1, 33, dtype=np.int32), max_new=4)
    eng.submit(probe)
    eng.step()                              # admission pumps one chunk
    st = eng.slots.states[0]
    assert st.phase == PREFILLING
    assert len(st.pages) == 1               # 8 of 32 prompt tokens landed
    assert eng.slots.allocator.reserved == st.budget - 1
    eng.step()                              # tick 2: next 8 tokens
    assert len(st.pages) == 2
    assert not hasattr(PagedSlotManager, "place")   # the graft path is gone
    res = eng.run()
    assert len(res[probe.rid].tokens) == 4
    _assert_drained(eng)


def test_tick_budget_bounds_mixed_batch(cfg, params):
    """Per-tick accounting: prefill tokens never exceed the budget and
    decode tokens never exceed the slot count, even while a long prompt
    streams in next to live decodes."""
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                           page_size=8, prefill_budget_tokens=4)
    eng.submit(Request(prompt=np.arange(1, 7, dtype=np.int32), max_new=12))
    eng.submit(Request(prompt=np.arange(1, 33, dtype=np.int32), max_new=4,
                       arrival_t=3.0))
    while len(eng.queue) or eng.slots.any_active():
        eng.step()
        assert eng.last_tick_prefill_tokens <= 4
        assert eng.last_tick_decode_tokens <= 2
    _assert_drained(eng)


# ---------------------------------------------------------------------------
# preempt/resume of mid-prefill sequences
# ---------------------------------------------------------------------------

def test_preempt_at_every_chunk_resume_exact(cfg, params):
    """Spill the probe after EVERY prefill chunk (including straight
    after admission, before any chunk lands) and after the first decode
    ticks — each resumed stream must equal the uninterrupted run, with
    a filler recycling the released pages in between."""
    prompt = np.arange(1, 15, dtype=np.int32)          # 14 tokens, 4 chunks
    budget, max_new = 4, 6
    mono = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                            kv_layout="contiguous")
    want = list(mono.run([Request(prompt=prompt.copy(),
                                  max_new=max_new)]).values())[0].tokens
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                           page_size=8, prefill_budget_tokens=budget)
    sched = PreemptiveScheduler(eng)
    n_chunks = -(-len(prompt) // budget)
    for k in range(n_chunks + 2):          # every chunk + 2 decode ticks
        probe = Request(prompt=prompt.copy(), max_new=max_new)
        sched.submit(probe)
        for _ in range(k + 1):             # step 1 admits + lands chunk 1
            sched.step()
        (slot,) = [s for s in eng.slots.active_slots()
                   if eng.slots.states[s].request.rid == probe.rid]
        assert probe.prefill_pos == min((k + 1) * budget, len(prompt))
        if k < n_chunks - 1:               # the (k+1)-th chunk just landed
            assert eng.slots.states[slot].phase == PREFILLING
        sched.preempt(slot)
        sched.submit(Request(prompt=prompt[:5].copy(), max_new=3))
        sched.step()                       # filler churns the pool
        sched.step()
        res = sched.run()
        np.testing.assert_array_equal(res[probe.rid].tokens, want)
        assert res[probe.rid].n_preemptions == 1
        _assert_drained(eng)
    assert sched.n_resumes == sched.n_preemptions


def test_preempt_before_first_chunk_no_snapshot(cfg, params):
    """A PREFILLING sequence spilled before any chunk landed has no KV
    to snapshot: the swap entry carries kv=None, resume re-reserves the
    budget and the chunks simply redo — still token-exact."""
    mono = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                            kv_layout="contiguous")
    prompt = np.arange(1, 10, dtype=np.int32)
    want = list(mono.run([Request(prompt=prompt.copy(),
                                  max_new=5)]).values())[0].tokens
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                           page_size=8, prefill_budget_tokens=4)
    sched = PreemptiveScheduler(eng)
    filler = Request(prompt=np.arange(1, 5, dtype=np.int32), max_new=6)
    probe = Request(prompt=prompt.copy(), max_new=5)
    sched.submit(filler)                   # filler's chunk eats the whole
    sched.submit(probe)                    # tick budget before the probe's
    sched.step()
    (slot,) = [s for s in eng.slots.active_slots()
               if eng.slots.states[s].request.rid == probe.rid]
    assert eng.slots.states[slot].pages == []
    sched.preempt(slot)
    entry = sched.swapped[probe.rid]
    assert entry.spilled and entry.kv is None
    res = sched.run()
    np.testing.assert_array_equal(res[probe.rid].tokens, want)
    assert res[probe.rid].n_preemptions == 1
    _assert_drained(eng)


# ---------------------------------------------------------------------------
# spill-store satellites: zstd codec + LRU eviction
# ---------------------------------------------------------------------------

@pytest.mark.skipif(zstd is None, reason="optional zstandard not installed")
def test_spill_codec_zstd_roundtrip_exact(cfg, params):
    """Compressed host entries: the delta merge decompresses the base,
    re-spilled streams stay token-exact, and compressed bytes are
    metered next to the raw ledger."""
    prompt = np.arange(1, 13, dtype=np.int32)
    mono = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                            kv_layout="contiguous")
    want = list(mono.run([Request(prompt=prompt.copy(),
                                  max_new=12)]).values())[0].tokens
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64, page_size=8)
    sched = PreemptiveScheduler(eng, spill_codec="zstd")
    probe = Request(prompt=prompt.copy(), max_new=12)
    sched.submit(probe)
    for _ in range(2):
        sched.step()
    sched.preempt(eng.slots.active_slots()[0])     # full spill (packed)
    sched.step()
    sched.step()
    sched.preempt(eng.slots.active_slots()[0])     # delta over packed base
    s = sched.stats()
    assert s["n_delta_spills"] == 1
    assert 0 < s["spill_bytes_compressed"]
    assert s["spill_bytes"] > 0
    res = sched.run()
    np.testing.assert_array_equal(res[probe.rid].tokens, want)
    _assert_drained(eng)


def test_spill_codec_requires_zstandard():
    if zstd is None:
        with pytest.raises(RuntimeError):
            DeltaSpillStore(8, codec="zstd")
    with pytest.raises(ValueError):
        DeltaSpillStore(8, codec="lz4")


def test_store_lru_eviction_caps_entries():
    store = DeltaSpillStore(2, max_entries=2)
    mk = lambda n: {"k": np.ones((1, 1, n * 2, 1), np.float32)}
    for rid in (1, 2, 3):
        store.merge(rid, mk(1), 0, 1)
    assert len(store) == 2 and 1 not in store      # LRU (rid 1) evicted
    assert store.take_evicted() == [1]
    assert store.take_evicted() == []              # drained once
    store.merge(2, None, 1, 1)                     # touch rid 2 -> MRU
    store.merge(4, mk(1), 0, 1)                    # now rid 3 is LRU
    assert 3 not in store and 2 in store
    assert store.stats()["n_store_evictions"] == 2
    assert store.stats()["spill_store_entries"] == 2


def test_store_max_bytes_eviction_and_accounting():
    store = DeltaSpillStore(2, max_bytes=100)
    mk = lambda n: {"k": np.ones((1, 1, n * 2, 8), np.float32)}  # 64B/page
    store.merge(1, mk(1), 0, 1)
    store.merge(2, mk(1), 0, 1)                    # 128B > cap: evict rid 1
    assert 1 not in store and 2 in store
    assert store.stats()["spill_store_bytes"] <= 100
    store.drop(2)
    assert store.stats()["spill_store_bytes"] == 0


def test_store_eviction_of_resumed_sequence_resets_watermark(cfg, params):
    """Regression: evicting the record of a sequence that already
    RESUMED must reset its live ``synced_pages`` watermark — otherwise
    its next spill would try to merge a delta into a record that no
    longer exists (or silently persist a partial snapshot)."""
    want = None
    mono = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                            kv_layout="contiguous")
    prompt = np.arange(1, 13, dtype=np.int32)
    want = list(mono.run([Request(prompt=prompt.copy(),
                                  max_new=14)]).values())[0].tokens
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64, page_size=8)
    sched = PreemptiveScheduler(eng, spill_max_entries=1)
    probe = Request(prompt=prompt.copy(), max_new=14)
    other = Request(prompt=np.arange(3, 12, dtype=np.int32), max_new=6)
    sched.submit(probe)
    sched.submit(other)
    sched.step()
    (slot,) = [s for s in eng.slots.active_slots()
               if eng.slots.states[s].request.rid == probe.rid]
    sched.preempt(slot)                # record created for probe
    sched.step()                       # probe resumes (watermark raised)
    (slot,) = [s for s in eng.slots.active_slots()
               if eng.slots.states[s].request.rid == other.rid]
    sched.preempt(slot)                # other's spill evicts probe's record
    sched.step()
    (slot,) = [s for s in eng.slots.active_slots()
               if eng.slots.states[s].request.rid == probe.rid]
    assert eng.slots.states[slot].synced_pages == 0    # watermark reset
    sched.preempt(slot)                # must be a FULL spill, not a delta
    res = sched.run()
    np.testing.assert_array_equal(res[probe.rid].tokens, want)
    assert sched.n_redo_from_prefill == 0
    _assert_drained(eng)


def test_store_eviction_triggers_redo_from_prefill(cfg, params):
    """Two spilled sequences against a 1-entry store: the first spill's
    record is evicted by the second, so the first sequence redoes from
    prefill — everything still finishes token-exact and accounted
    (resumes + redos == preemptions)."""
    prompts = [np.arange(1, 10, dtype=np.int32),
               np.arange(2, 11, dtype=np.int32)]
    want = []
    for p in prompts:                      # fresh engine per reference run
        mono = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                                kv_layout="contiguous")
        res = mono.run([Request(prompt=p.copy(), max_new=8)])
        want.append(list(res.values())[0].tokens)
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64, page_size=8)
    sched = PreemptiveScheduler(eng, spill_max_entries=1)
    reqs = [Request(prompt=p.copy(), max_new=8) for p in prompts]
    for r in reqs:
        sched.submit(r)
    sched.step()
    for slot in list(eng.slots.active_slots()):    # spill both in-flight
        sched.preempt(slot)
    sched.step(decode=False)
    assert sched.n_redo_from_prefill == 1          # first record evicted
    assert reqs[0].rid not in sched.swapped        # requeued, not swapped
    res = sched.run()
    for r, w in zip(reqs, want):
        np.testing.assert_array_equal(res[r.rid].tokens, w)
    assert sched.n_resumes + sched.n_redo_from_prefill == sched.n_preemptions
    stats = sched.stats()
    assert stats["n_store_evictions"] == 1
    assert stats["n_redo_from_prefill"] == 1
    assert len(sched.store) == 0
    _assert_drained(eng)
