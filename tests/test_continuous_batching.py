"""Continuous-batching engine: slot admission/eviction, mid-flight join
determinism, backpressure, and the ragged-length attention paths it
relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_reduced_config
from repro.models import transformer as T
from repro.serving.batching import (QueueFull, Request, RequestQueue,
                                    poisson_trace)
from repro.serving.engine import ContinuousEngine, ServingEngine

from helpers import f32_cfg


@pytest.fixture(scope="module")
def cfg():
    return f32_cfg("smollm-360m")


@pytest.fixture(scope="module")
def params(cfg):
    return T.init_params(jax.random.PRNGKey(0), cfg, max_seq=64)


def _req(rng, n, max_new, arrival_t=0.0, vocab=64):
    return Request(prompt=rng.integers(1, vocab, n).astype(np.int32),
                   max_new=max_new, arrival_t=arrival_t)


# ---------------------------------------------------------------------------
# admission / eviction
# ---------------------------------------------------------------------------

def test_admission_and_eviction_order(cfg, params):
    """Requests are admitted FIFO into the lowest free slot; a short
    request finishes first and its slot is reused by the queued one."""
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    short = _req(rng, 5, 3)
    long = _req(rng, 5, 12)
    queued = _req(rng, 5, 3)
    for r in (short, long, queued):
        eng.submit(r)

    eng.step()                             # admits short+long, 1 decode step
    assert eng.slots.states[0].request.rid == short.rid
    assert eng.slots.states[1].request.rid == long.rid
    assert len(eng.queue) == 1             # queued waits: no free slot

    while queued.rid not in eng.results or long.rid not in eng.results:
        eng.step()
    # short finished first; queued joined mid-flight in short's slot and
    # still finished before the long request drained
    assert eng.finish_order == [short.rid, queued.rid, long.rid]
    q_res = eng.results[queued.rid]
    assert q_res.admitted_step < eng.results[long.rid].finished_step
    assert len(q_res.tokens) == 3


def test_all_results_complete(cfg, params):
    eng = ContinuousEngine(cfg, params, n_slots=3, max_seq=64)
    trace = poisson_trace(9, rate=0.8, prompt_lens=(3, 12), max_new=(1, 9),
                          vocab_size=cfg.vocab_size, seed=3)
    results = eng.run(trace)
    assert sorted(results) == sorted(r.rid for r in trace)
    by_rid = {r.rid: r for r in trace}
    for rid, res in results.items():
        assert len(res.tokens) == by_rid[rid].max_new
        assert res.finished_step >= res.admitted_step


# ---------------------------------------------------------------------------
# determinism: joining mid-flight must not change a sequence's tokens
# ---------------------------------------------------------------------------

def test_midflight_join_matches_solo_run(cfg, params):
    rng = np.random.default_rng(1)
    probe = _req(rng, 9, 7)
    filler = _req(rng, 13, 10)

    solo = ContinuousEngine(cfg, params, n_slots=2, max_seq=64)
    want = solo.run([Request(prompt=probe.prompt, max_new=probe.max_new)])
    (want_tokens,) = [r.tokens for r in want.values()]

    joint = ContinuousEngine(cfg, params, n_slots=2, max_seq=64)
    probe.arrival_t = 4.0                  # joins while filler is decoding
    got = joint.run([filler, probe])
    np.testing.assert_array_equal(got[probe.rid].tokens, want_tokens)


@pytest.mark.slow   # compiles prefill+decode for one arch per family
@pytest.mark.parametrize("arch", [
    "qwen3-moe-30b-a3b",    # moe (drop-free routing path)
    "deepseek-v3-671b",     # MLA per-slot absorbed decode
    "zamba2-7b",            # hybrid: recurrent state + shared attn
    "xlstm-1.3b",           # pure recurrent (exact-length admission)
])
def test_midflight_join_matches_solo_all_families(arch):
    fam_cfg = f32_cfg(arch)
    fam_params = T.init_params(jax.random.PRNGKey(0), fam_cfg, max_seq=64)
    rng = np.random.default_rng(6)
    probe = Request(prompt=rng.integers(
        1, fam_cfg.vocab_size, 6).astype(np.int32), max_new=5)
    filler = Request(prompt=rng.integers(
        1, fam_cfg.vocab_size, 9).astype(np.int32), max_new=7)

    solo = ContinuousEngine(fam_cfg, fam_params, n_slots=2, max_seq=64)
    want = solo.run([Request(prompt=probe.prompt, max_new=probe.max_new)])
    (want_tokens,) = [r.tokens for r in want.values()]

    joint = ContinuousEngine(fam_cfg, fam_params, n_slots=2, max_seq=64)
    probe.arrival_t = 2.0
    got = joint.run([filler, probe])
    np.testing.assert_array_equal(got[probe.rid].tokens, want_tokens)


def test_continuous_matches_fixed_slot_engine(cfg, params):
    """Same params, same prompt: the continuous engine's greedy tokens
    equal the seed fixed-slot engine's."""
    rng = np.random.default_rng(2)
    prompt = rng.integers(1, cfg.vocab_size, 11).astype(np.int32)
    fixed = ServingEngine(cfg, params, max_seq=64)
    want = fixed.generate(prompt[None], max_new=6).tokens[0]
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64)
    got = eng.run([Request(prompt=prompt, max_new=6)])
    np.testing.assert_array_equal(list(got.values())[0].tokens, want)


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------

def test_full_queue_backpressure(cfg, params):
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                           queue_capacity=3)
    rng = np.random.default_rng(4)
    for _ in range(3):
        eng.submit(_req(rng, 4, 2))
    with pytest.raises(QueueFull):
        eng.submit(_req(rng, 4, 2))
    eng.step()                             # admission frees queue space
    assert len(eng.queue) == 1
    eng.submit(_req(rng, 4, 2))            # accepted again
    results = eng.run()
    assert len(results) == 4


def test_submit_rejects_overlong_request(cfg, params):
    eng = ContinuousEngine(cfg, params, n_slots=1, max_seq=16)
    rng = np.random.default_rng(5)
    with pytest.raises(ValueError):
        eng.submit(_req(rng, 12, 8))       # 12 + 8 > 16
    with pytest.raises(ValueError):
        eng.submit(_req(rng, 4, 0))        # prefill always emits one token


def test_unsupported_family_raises(params):
    vlm = get_reduced_config("qwen2-vl-2b")
    with pytest.raises(NotImplementedError):
        ContinuousEngine(vlm, {}, n_slots=1, max_seq=32)


# ---------------------------------------------------------------------------
# ragged-length attention plumbing the engine depends on
# ---------------------------------------------------------------------------

def test_decode_step_vector_pos_matches_scalar(cfg, params):
    """With every slot at the SAME depth, the per-slot path must agree
    with the scalar path bit-for-bit."""
    B, S = 3, 8
    toks = jax.random.randint(jax.random.PRNGKey(0), (B, S), 0,
                              cfg.vocab_size)
    from repro.serving.engine import _graft
    _, _, cache = T.forward(params, cfg, {"tokens": toks},
                            return_cache=True, remat=False)
    cache = jax.tree.map(_graft, T.init_cache(cfg, B, 32), cache)
    nxt = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0,
                             cfg.vocab_size)
    lo_s, _ = T.decode_step(params, cfg, cache, nxt, jnp.int32(S))
    lo_v, _ = T.decode_step(params, cfg, cache, nxt,
                            jnp.full((B,), S, jnp.int32))
    np.testing.assert_array_equal(np.asarray(lo_s), np.asarray(lo_v))


def test_chunked_attention_per_sequence_kv_len():
    from repro.models.attention import chunked_attention
    key = jax.random.PRNGKey(0)
    B, S, H, D = 3, 16, 2, 8
    q = jax.random.normal(key, (B, 1, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D))
    lens = jnp.asarray([3, 9, 16], jnp.int32)
    batched = chunked_attention(q, k, v, causal=False, kv_len=lens)
    for i, n in enumerate([3, 9, 16]):
        solo = chunked_attention(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                                 causal=False, kv_len=jnp.int32(n))
        np.testing.assert_allclose(np.asarray(batched[i]),
                                   np.asarray(solo[0]), atol=1e-6)


def test_decode_kernel_per_sequence_kv_len():
    from repro.kernels import ops, ref
    B, S, H, Hkv, D = 3, 128, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D))
    lens = jnp.asarray([1, 57, 128], jnp.int32)
    got = ops.decode_attention(q, k, v, lens, block_k=64)
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_graft_slot_cache_writes_only_target_slot(cfg, params):
    big = T.init_cache(cfg, 3, 32)
    toks = jax.random.randint(jax.random.PRNGKey(0), (1, 8), 0,
                              cfg.vocab_size)
    _, _, small = T.forward(params, cfg, {"tokens": toks},
                            return_cache=True, remat=False)
    out = T.graft_slot_cache(big, small, jnp.int32(1))
    for leaf_b, leaf_o, leaf_s in zip(jax.tree.leaves(big),
                                      jax.tree.leaves(out),
                                      jax.tree.leaves(small)):
        # untouched slots identical (zeros), target slot holds the prefix
        np.testing.assert_array_equal(np.asarray(leaf_o[:, 0]),
                                      np.asarray(leaf_b[:, 0]))
        np.testing.assert_array_equal(np.asarray(leaf_o[:, 2]),
                                      np.asarray(leaf_b[:, 2]))
        got = np.asarray(leaf_o[:, 1, :leaf_s.shape[2]], np.float32)
        np.testing.assert_array_equal(got,
                                      np.asarray(leaf_s[:, 0], np.float32))
