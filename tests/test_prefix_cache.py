"""Prefix-sharing copy-on-write paged KV: the radix index, refcounted
attach, CoW forking, spill/resume pinning, and end-to-end token
exactness with the unshared engine."""
import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.serving.batching import Request
from repro.serving.engine import ContinuousEngine
from repro.serving.paging import BlockAllocator, PagePrefixIndex, PoolExhausted
from repro.serving.scheduler import PreemptiveScheduler

from helpers import f32_cfg

PS = 16


@pytest.fixture(scope="module")
def cfg():
    return f32_cfg("smollm-360m")


@pytest.fixture(scope="module")
def params(cfg):
    return T.init_params(jax.random.PRNGKey(0), cfg, max_seq=128)


def _engine(cfg, params, *, prefix_cache, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_seq", 128)
    return ContinuousEngine(cfg, params, kv_layout="paged", page_size=PS,
                            prefix_cache=prefix_cache, **kw)


def _shared_trace(cfg, *, n=8, header_pages=2, seed=3):
    """n requests over ONE header of ``header_pages`` full pages, each
    with a unique tail; the last request repeats request 0's full
    prompt (a fully-covered admission)."""
    rng = np.random.default_rng(seed)
    header = rng.integers(1, cfg.vocab_size, header_pages * PS).astype(
        np.int32)
    out = []
    for i in range(n - 1):
        tail = rng.integers(1, cfg.vocab_size, 3 + i).astype(np.int32)
        out.append(Request(prompt=np.concatenate([header, tail]),
                           max_new=4, arrival_t=float(2 * i)))
    out.append(Request(prompt=out[0].prompt.copy(), max_new=3,
                       arrival_t=float(2 * n)))
    return out


def _drained(eng):
    a = eng.slots.allocator
    if eng.slots.prefix_index is not None:
        eng.slots.prefix_index.clear()
    return (a.in_use == 0 and a.reserved == 0 and a.n_live_refs() == 0
            and len(a._free) == a.n_pages)


# ---------------------------------------------------------------------------
# the radix index in isolation
# ---------------------------------------------------------------------------

def test_prefix_index_match_attach_evict_refcounts():
    a = BlockAllocator(8)
    idx = PagePrefixIndex(a, 4)
    toks = np.arange(1, 13, dtype=np.int32)       # 3 full pages
    a.reserve(3)
    pages = a.alloc(3)
    idx.insert(toks, pages)
    assert all(a.refcount(p) == 2 for p in pages)  # caller + index
    a.release(pages)                               # caller finishes
    assert all(a.refcount(p) == 1 for p in pages)  # index keeps them live
    assert a.in_use == 3 and idx.reclaimable() == 3

    hit = idx.match(toks)
    assert list(hit) == list(pages)
    assert idx.match(toks[:7]) == pages[:1]        # page-granular: 1 full page
    assert idx.match(np.flip(toks).copy()) == []
    for got in (3, 1, 0):                          # admission accounting
        idx.note_attach(got)
    assert idx.hits == 2 and idx.misses == 1 and idx.pages_attached == 4

    # eviction is leaf-first and returns pages to the pool
    freed = idx.evict(1)
    assert freed == 1 and a.in_use == 2
    assert idx.match(toks) == pages[:2]            # prefix survives
    idx.clear()
    assert a.in_use == 0 and a.n_live_refs() == 0


def test_prefix_index_shared_interior_survives_leaf_eviction():
    a = BlockAllocator(8)
    idx = PagePrefixIndex(a, 4)
    head = np.arange(1, 5, dtype=np.int32)
    for salt in (50, 60):                          # two branches, one head
        toks = np.concatenate([head, np.arange(salt, salt + 4,
                                               dtype=np.int32)])
        a.reserve(2)
        idx.insert(toks, a.alloc(2))
    # first-writer-wins: the second branch's duplicate head copy was
    # never indexed, so once both callers finish it frees outright —
    # leaving head + two tails (all rc==1, held only by the index).
    # The head is INTERIOR: evicting 1 page must take a LEAF.
    for p in range(1, 5):
        a.release([p])                             # callers all finished
    assert a.in_use == 3 and idx.reclaimable() == 3
    idx.evict(1)
    assert len(idx.match(np.concatenate(
        [head, np.arange(50, 54, dtype=np.int32)]))) + len(idx.match(
            np.concatenate([head, np.arange(60, 64,
                                            dtype=np.int32)]))) == 3
    idx.clear()
    assert a.in_use == 0


def test_share_of_free_page_raises():
    a = BlockAllocator(4)
    with pytest.raises(PoolExhausted):
        a.share([1])
    a.reserve(1)
    pages = a.alloc(1)
    a.share(pages)
    a.release(pages)
    assert a.refcount(pages[0]) == 1 and a.in_use == 1
    a.release(pages)
    assert a.in_use == 0
    with pytest.raises(PoolExhausted):
        a.release(pages)                           # refcount 0 is final


# ---------------------------------------------------------------------------
# end-to-end: shared serving is token-exact and does less work
# ---------------------------------------------------------------------------

def test_shared_replay_token_exact_and_cheaper(cfg, params):
    trace = _shared_trace(cfg)
    runs = {}
    for pc in (True, False):
        eng = _engine(cfg, params, prefix_cache=pc)
        res = eng.run([r.clone() for r in trace])
        toks = [res[k].tokens for k in sorted(res)]
        runs[pc] = (eng, toks)
    eng_s, toks_s = runs[True]
    eng_u, toks_u = runs[False]
    assert len(toks_s) == len(toks_u)
    for a, b in zip(toks_s, toks_u):
        np.testing.assert_array_equal(a, b)
    # sharing skipped real prompt work and real pages
    assert eng_s.prefill_tokens_total < eng_u.prefill_tokens_total
    assert (eng_s.slots.allocator.peak_in_use
            < eng_u.slots.allocator.peak_in_use)
    stats = eng_s.kv_cache_stats()
    assert stats["prefix_hits"] > 0
    assert stats["prefill_positions_skipped"] > 0
    assert _drained(eng_s) and _drained(eng_u)


def test_fully_covered_prompt_pays_one_position(cfg, params):
    """A duplicate prompt re-runs ONLY its final position (for the
    first token's logits) — and CoW-forks the page it rewrites."""
    rng = np.random.default_rng(9)
    prompt = rng.integers(1, cfg.vocab_size, 2 * PS).astype(np.int32)
    first = Request(prompt=prompt.copy(), max_new=4, arrival_t=0.0)
    dup = Request(prompt=prompt.copy(), max_new=4, arrival_t=50.0)
    eng = _engine(cfg, params, prefix_cache=True)
    res = eng.run([first, dup])
    assert eng.slots.cow_copies >= 1               # last shared page forked
    # full prompt charged once, the duplicate charged 1 position
    assert eng.prefill_tokens_total == len(prompt) + 1
    toks = [res[k].tokens for k in sorted(res)]
    np.testing.assert_array_equal(toks[0][:4], toks[1][:4])
    assert _drained(eng)


def test_cow_fork_never_corrupts_the_cached_prefix(cfg, params):
    """Serve header+A, then header+B, then header+A again: if the CoW
    fork failed to copy (or wrote through a shared page), the third
    run would decode from corrupted header KV."""
    rng = np.random.default_rng(21)
    header = rng.integers(1, cfg.vocab_size, 2 * PS).astype(np.int32)
    tails = [rng.integers(1, cfg.vocab_size, 5).astype(np.int32)
             for _ in range(2)]
    mk = lambda t, at: Request(prompt=np.concatenate([header, t]),
                               max_new=6, arrival_t=at)
    trace = [mk(tails[0], 0.0), mk(tails[1], 20.0), mk(tails[0], 40.0)]
    eng = _engine(cfg, params, prefix_cache=True, n_slots=1)
    res = eng.run([r.clone() for r in trace])
    ref = _engine(cfg, params, prefix_cache=False, n_slots=1).run(
        [r.clone() for r in trace])
    for a, b in _pairs(res, ref):
        np.testing.assert_array_equal(a, b)
    assert _drained(eng)


def _pairs(res_a, res_b):
    return [(res_a[a].tokens, res_b[b].tokens)
            for a, b in zip(sorted(res_a), sorted(res_b))]


# ---------------------------------------------------------------------------
# sharing x preemption: spills ship private pages only, resume re-pins
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("delta_spill", [False, True])
def test_spill_resume_with_shared_prefixes_token_exact(cfg, params,
                                                       delta_spill):
    trace = _shared_trace(cfg, n=6)
    ref = _engine(cfg, params, prefix_cache=False).run(
        [r.clone() for r in trace])

    eng = _engine(cfg, params, prefix_cache=True, n_slots=2)
    sched = PreemptiveScheduler(eng, preempt_mode="spill",
                                delta_spill=delta_spill)
    for r in sorted(trace, key=lambda r: r.arrival_t):
        sched.submit(r.clone())
    tick = 0
    spilled_private = []
    while sched.has_work():
        tick += 1
        assert tick < 2000
        if tick % 7 == 0:
            for slot in list(eng.slots.active_slots()):
                st = eng.slots.states[slot]
                shared_before = st.shared_pages
                n_pages = len(st.pages)
                sched.preempt(slot, "spill")
                # the swap entry retains EXACTLY the shared prefix
                entry = sched.swapped[st.request.rid]
                assert len(entry.state.pages) == shared_before
                spilled_private.append(n_pages - shared_before)
        sched.step()
    assert sched.n_preemptions > 0 and any(n > 0 for n in spilled_private)
    for a, b in _pairs(eng.results, ref):
        np.testing.assert_array_equal(a, b)
    assert sched.n_resumes == sched.n_preemptions
    assert _drained(eng)


def test_store_eviction_redo_releases_pinned_prefix(cfg, params):
    """A spill-store eviction while the sequence is swapped out must
    drop the swap entry's pinned shared refs (discard_detached), and
    the redo must still finish token-exactly."""
    trace = _shared_trace(cfg, n=5)
    ref = _engine(cfg, params, prefix_cache=False).run(
        [r.clone() for r in trace])
    eng = _engine(cfg, params, prefix_cache=True, n_slots=2)
    sched = PreemptiveScheduler(eng, preempt_mode="spill", delta_spill=True,
                                spill_max_entries=1)
    for r in sorted(trace, key=lambda r: r.arrival_t):
        sched.submit(r.clone())
    tick = 0
    while sched.has_work():
        tick += 1
        assert tick < 3000
        if tick % 5 == 0:
            for slot in list(eng.slots.active_slots()):
                sched.preempt(slot, "spill")
        sched.step()
    for a, b in _pairs(eng.results, ref):
        np.testing.assert_array_equal(a, b)
    assert _drained(eng)


# ---------------------------------------------------------------------------
# config guards
# ---------------------------------------------------------------------------

def test_prefix_cache_requires_paged_layout(cfg, params):
    with pytest.raises(ValueError):
        ContinuousEngine(cfg, params, kv_layout="contiguous",
                         prefix_cache=True)
