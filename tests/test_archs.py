"""Per-architecture smoke tests: a REDUCED variant of each assigned
family runs one forward/train step and one decode step on CPU, asserting
output shapes and finiteness (assignment requirement f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import ARCH_IDS, INPUT_SHAPES, get_config, get_reduced_config, supports_shape
from repro.models import transformer as T
from repro.training import optim
from repro.training.loop import init_state, train

from helpers import make_batch

pytestmark = pytest.mark.slow   # trains/decodes every assigned arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = get_reduced_config(arch)
    B, S = 2, 64
    params = T.init_params(jax.random.PRNGKey(0), cfg, max_seq=S)
    batch = make_batch(cfg, B, S)
    logits, aux = T.forward(params, cfg, batch)
    S_total = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = T.loss_fn(params, cfg, batch)
    assert jnp.isfinite(loss)
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    cfg = get_reduced_config(arch)
    B, S = 2, 32
    opt_cfg = optim.OptimConfig(lr=1e-3, warmup_steps=1, total_steps=4)
    params = T.init_params(jax.random.PRNGKey(0), cfg, max_seq=S)
    state = optim.adamw_init(params, opt_cfg)
    batch = make_batch(cfg, B, S)

    def lf(p):
        return T.loss_fn(p, cfg, batch)

    (_, m0), grads = jax.value_and_grad(lf, has_aux=True)(params)
    params2, state, om = optim.adamw_update(params, grads, state, opt_cfg)
    assert jnp.isfinite(om["grad_norm"]) and float(om["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, params2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_reduced_config(arch)
    B, S_max = 2, 64
    params = T.init_params(jax.random.PRNGKey(0), cfg, max_seq=S_max)
    cache = T.init_cache(cfg, B, S_max)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = T.decode_step(params, cfg, cache, tok, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_constructs(arch):
    """Full configs are exercised via the dry-run only; here we check the
    exact assigned numbers are loadable and countable."""
    cfg = get_config(arch)
    n = cfg.param_count()
    assert n > 1e7
    for shape in INPUT_SHAPES.values():
        supports_shape(cfg, shape)   # must not raise


def test_reduced_configs_are_reduced():
    for arch in ARCH_IDS:
        r = get_reduced_config(arch)
        assert r.n_layers <= 2 and r.d_model <= 512
        if r.moe:
            assert r.moe.n_experts <= 4
