"""Fault injection and crash-safe serving: the seeded ``core.faults``
plan, the framed CRC+NACK transmit lane, spill-record integrity, and
token-exact checkpoint/restore across an injected satellite reboot.

The oracle throughout: a fault plan may cost time and bytes — it must
NEVER change an answer.  Every replay under faults is compared
token-for-token against its fault-free twin.
"""
import jax
import numpy as np
import pytest

from repro.core.faults import FaultInjector, FaultPlan
from repro.core.gating import ConfidenceGate
from repro.core.link import ContactSchedule, TransmitLane
from repro.models import transformer as T
from repro.serving.batching import Request
from repro.serving.engine import ContinuousEngine
from repro.serving.paging import DeltaSpillStore, SpillCorruption
from repro.serving.scheduler import (PreemptiveScheduler,
                                     SpaceGroundScheduler)

from helpers import f32_cfg


@pytest.fixture(scope="module")
def cfg():
    return f32_cfg("smollm-360m")


@pytest.fixture(scope="module")
def params(cfg):
    return T.init_params(jax.random.PRNGKey(0), cfg, max_seq=64)


def _prompt(rng, n, vocab):
    return rng.integers(1, vocab, n).astype(np.int32)


def _assert_drained(eng):
    alloc = getattr(eng.slots, "allocator", None)
    if alloc is not None:
        assert alloc.in_use == 0 and alloc.reserved == 0
        assert alloc.n_live_refs() == 0


# ---------------------------------------------------------------------------
# FaultPlan / FaultInjector
# ---------------------------------------------------------------------------

def test_fault_plan_validates_rates():
    with pytest.raises(ValueError):
        FaultPlan(frame_loss_rate=0.7, frame_corrupt_rate=0.5)  # sum > 1
    with pytest.raises(ValueError):
        FaultPlan(frame_loss_rate=-0.1)
    with pytest.raises(ValueError):
        FaultPlan(truncate_every=1, truncate_frac=0.0)
    FaultPlan(frame_loss_rate=1.0)                              # boundary ok


def test_injector_deterministic_and_counted():
    plan = FaultPlan(seed=5, frame_loss_rate=0.3, frame_corrupt_rate=0.2)
    inj1, inj2 = FaultInjector(plan), FaultInjector(plan)
    fates1 = [inj1.frame_fate() for _ in range(200)]
    fates2 = [inj2.frame_fate() for _ in range(200)]
    assert fates1 == fates2                       # seeded: replayable
    assert inj1.n_frames_lost == fates1.count("lost") > 0
    assert inj1.n_frame_corruptions == fates1.count("corrupt") > 0
    assert inj1.n_corruptions_injected == inj1.n_frame_corruptions


def test_injector_fate_short_circuits_when_disarmed():
    """A rate-free plan must not consume RNG draws, so arming only the
    spill fault leaves the frame stream untouched (and vice versa)."""
    inj = FaultInjector(FaultPlan(seed=0, spill_corrupt_every=2))
    before = inj.state()["rng"]
    assert all(inj.frame_fate() == "ok" for _ in range(50))
    assert inj.state()["rng"] == before


def test_injector_state_roundtrip_replays_stream():
    plan = FaultPlan(seed=9, frame_loss_rate=0.25, frame_corrupt_rate=0.25)
    inj = FaultInjector(plan)
    [inj.frame_fate() for _ in range(37)]
    mid = inj.state()
    tail = [inj.frame_fate() for _ in range(50)]
    inj2 = FaultInjector(plan)
    inj2.load_state(mid)
    assert [inj2.frame_fate() for _ in range(50)] == tail
    assert inj2.n_frames_lost == inj.n_frames_lost


def test_corrupt_bytes_flips_exactly_one_bit():
    inj = FaultInjector(FaultPlan(seed=1))
    data = bytes(range(64))
    bad = inj.corrupt_bytes(data)
    assert len(bad) == len(data) and bad != data
    diff = [a ^ b for a, b in zip(data, bad) if a != b]
    assert len(diff) == 1 and bin(diff[0]).count("1") == 1


def test_truncate_step_windows_every_kth():
    inj = FaultInjector(FaultPlan(seed=0, truncate_every=2,
                                  truncate_frac=0.5))
    wins = [(0, 10), (20, 30), (40, 50), (60, 70)]
    out = inj.truncate_step_windows(wins)
    assert out[0] == (0, 10) and out[2] == (40, 50)   # untouched
    assert out[1] == (20, 25) and out[3] == (60, 65)  # cut to 50%
    assert inj.n_windows_truncated == 2


# ---------------------------------------------------------------------------
# framed transmit lane
# ---------------------------------------------------------------------------

def test_framed_lossless_matches_unframed_goodput():
    """Without faults, framing is invisible whenever the tick budget is
    frame-aligned: same completions in the same order, same goodput
    bytes (frames are whole-or-nothing, so a non-aligned budget may
    legally trail the byte-granular lane within a tick)."""
    plain, framed = TransmitLane(), TransmitLane(frame_bytes=25)
    for lane in (plain, framed):
        lane.enqueue("a", 100.0)
        lane.enqueue("b", 75.0)
    for _ in range(4):
        assert plain.tick(50.0) == framed.tick(50.0)
    assert framed.bytes_sent == plain.bytes_sent == 175.0
    assert framed.n_completed == 2
    assert framed.n_corruptions_detected == 0
    assert framed.frame_bytes_attempted == 175.0


def test_framed_lossy_delivers_all_and_detects_all():
    inj = FaultInjector(FaultPlan(seed=2, frame_loss_rate=0.3,
                                  frame_corrupt_rate=0.2))
    lane = TransmitLane(frame_bytes=32, max_retries=16, injector=inj)
    sizes = [100.0, 50.0, 200.0, 10.0]
    for i, nb in enumerate(sizes):
        lane.enqueue(i, nb)
    done = []
    for _ in range(400):
        done += lane.tick(64.0)
        if len(lane) == 0:
            break
    assert sorted(done) == [0, 1, 2, 3]          # ARQ delivered everything
    assert lane.n_retransmits > 0 and lane.bytes_retransmitted > 0
    assert lane.n_frames_lost == inj.n_frames_lost > 0
    assert lane.n_corruptions_detected == inj.n_frame_corruptions > 0
    assert lane.n_silent_corruptions == 0
    assert lane.bytes_sent == sum(sizes)         # goodput: payload bytes once
    assert abs(lane.frame_bytes_attempted
               - (lane.bytes_sent + lane.bytes_lost + lane.bytes_corrupt)
               ) < 1e-9


def test_framed_retry_exhaustion_fails_payload():
    inj = FaultInjector(FaultPlan(seed=0, frame_loss_rate=1.0))
    lane = TransmitLane(frame_bytes=32, max_retries=2, injector=inj)
    lane.enqueue("doomed", 48.0)
    for _ in range(20):
        assert lane.tick(64.0) == []
        if lane.n_payload_failures:
            break
    assert lane.n_payload_failures == 1
    assert lane.take_failed() == [("doomed", 48.0)]   # caller may re-enqueue
    assert len(lane) == 0 and lane.bytes_sent == 0.0


def test_framed_lane_rejects_bad_config():
    with pytest.raises(ValueError):
        TransmitLane(frame_bytes=0)
    with pytest.raises(ValueError):
        TransmitLane(injector=FaultInjector(FaultPlan()))  # needs framing


# ---------------------------------------------------------------------------
# spill-record integrity
# ---------------------------------------------------------------------------

def _kv(pages, ps=4, fill=1.0):
    return {"k": np.full((1, 2, pages * ps, 3), fill, np.float32)}


def test_spill_store_detects_manual_corruption_at_snapshot():
    store = DeltaSpillStore(4)
    store.merge(7, _kv(2), 0, 2)
    rec = store.record(7)
    rec.kv["k"][0, 0, 0, 0] += 1.0               # bit rot on the host copy
    with pytest.raises(SpillCorruption):
        store.snapshot(7)
    assert 7 not in store                        # discarded, never grafted
    assert store.stats()["n_spill_corruptions_detected"] == 1
    assert store.stored_bytes == 0


def test_spill_store_detects_corrupt_base_at_merge():
    store = DeltaSpillStore(4)
    store.merge(7, _kv(2), 0, 2)
    store.record(7).kv["k"][0, 0, 0, 0] += 1.0
    with pytest.raises(SpillCorruption):
        store.merge(7, _kv(1, fill=2.0), 2, 3)   # delta onto a rotten base
    assert 7 not in store
    # recovery: a FULL re-spill (synced=0) re-establishes the record
    store.merge(7, _kv(3, fill=3.0), 0, 3)
    np.testing.assert_array_equal(store.snapshot(7)["k"],
                                  _kv(3, fill=3.0)["k"])


def test_spill_store_injector_corrupts_then_detects():
    inj = FaultInjector(FaultPlan(seed=0, spill_corrupt_every=2))
    store = DeltaSpillStore(4, injector=inj)
    store.merge(1, _kv(2), 0, 2)                 # merge 1: clean
    store.merge(2, _kv(2), 0, 2)                 # merge 2: injected
    np.testing.assert_array_equal(store.snapshot(1)["k"], _kv(2)["k"])
    with pytest.raises(SpillCorruption):
        store.snapshot(2)
    assert inj.n_spill_corruptions == 1
    assert store.stats()["n_spill_corruptions_detected"] == 1


def test_spill_store_counter_roundtrip():
    store = DeltaSpillStore(4)
    store.merge(1, _kv(2), 0, 2)
    store.drop(1)
    other = DeltaSpillStore(4)
    other.load_counters(store.counters())
    assert other.counters() == store.counters()


# ---------------------------------------------------------------------------
# scheduler: redo-from-corruption, checkpoint/restore
# ---------------------------------------------------------------------------

def _reqs(cfg, n, seed=0, max_new=8):
    rng = np.random.default_rng(seed)
    return [Request(prompt=_prompt(rng, 10, cfg.vocab_size),
                    max_new=max_new, arrival_t=float(i)) for i in range(n)]


def test_scheduler_redo_from_corruption_token_exact(cfg, params):
    """Every spill lands corrupted (spill_corrupt_every=1): each resume
    detects it, redoes from prefill, and still produces the exact
    uninterrupted token stream — corruption never grafts garbage."""
    reqs = _reqs(cfg, 3)
    ref = ContinuousEngine(cfg, params, n_slots=2, max_seq=64).run(
        [r.clone() for r in reqs])
    ref_toks = [res.tokens for _, res in sorted(ref.items())]

    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                           kv_layout="paged", page_size=8, pool_pages=12)
    inj = FaultInjector(FaultPlan(seed=0, spill_corrupt_every=1))
    sched = PreemptiveScheduler(eng, delta_spill=True, fault_injector=inj)
    for r in reqs:
        sched.submit(r)
    for _ in range(4):
        sched.step()
    sched.preempt_all()                          # spills → all corrupted
    while sched.has_work():
        sched.step()
    got = [res.tokens for _, res in sorted(sched.results.items())]
    assert len(got) == 3
    for a, b in zip(got, ref_toks):
        np.testing.assert_array_equal(a, b)
    assert sched.n_redo_from_corruption >= 1
    assert inj.n_spill_corruptions >= 1
    assert sched.stats()["n_spill_corruptions_detected"] >= 1
    assert len(sched.store) == 0
    _assert_drained(eng)


def test_checkpoint_restore_roundtrip_token_exact(cfg, params, tmp_path):
    """Checkpoint mid-flight (active + swapped + queued sequences all
    live), restore into a FRESH engine, and both the original and the
    restored run finish with the uninterrupted run's exact tokens."""
    reqs = _reqs(cfg, 4)
    ref = ContinuousEngine(cfg, params, n_slots=2, max_seq=64).run(
        [r.clone() for r in reqs])
    ref_toks = [res.tokens for _, res in sorted(ref.items())]

    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                           kv_layout="paged", page_size=8, pool_pages=12,
                           prefill_budget_tokens=8)
    sched = PreemptiveScheduler(eng, delta_spill=True)
    for r in reqs:
        sched.submit(r)
    for _ in range(9):
        sched.step()
    sched.preempt_all()                          # swap ledger non-empty
    path = str(tmp_path / "sat.ckpt")
    assert sched.checkpoint(path, extra_meta={"tick": 9}) > 0

    while sched.has_work():                      # original keeps running:
        sched.step()                             # checkpoint is non-destructive
    orig = [res.tokens for _, res in sorted(sched.results.items())]

    sched2 = PreemptiveScheduler(eng.clone_fresh(), delta_spill=True)
    assert sched2.restore(path) == {"tick": 9}
    while sched2.has_work():
        sched2.step()
    rest = [res.tokens for _, res in sorted(sched2.results.items())]
    assert len(orig) == len(rest) == 4
    for a, b, c in zip(orig, rest, ref_toks):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(b, c)
    _assert_drained(sched2.engine)


def test_restore_requires_fresh_engine(cfg, params, tmp_path):
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                           kv_layout="paged", page_size=8, pool_pages=12)
    sched = PreemptiveScheduler(eng)
    sched.submit(_reqs(cfg, 1)[0])
    path = str(tmp_path / "sat.ckpt")
    sched.checkpoint(path)
    sched.step()                                 # no longer fresh
    with pytest.raises(RuntimeError, match="FRESH"):
        sched.restore(path)


# ---------------------------------------------------------------------------
# space-ground: fault-armed end-to-end
# ---------------------------------------------------------------------------

def _sg_trace(cfg, n=6, seed=8):
    rng = np.random.default_rng(seed)
    return [Request(prompt=_prompt(rng, int(rng.integers(8, 14)),
                                   cfg.vocab_size),
                    max_new=int(rng.integers(8, 14)),
                    arrival_t=float(i * 2)) for i in range(n)]


def _sg(cfg, params, **kw):
    sat = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                           kv_layout="paged", page_size=8, pool_pages=9,
                           prefill_budget_tokens=8)
    gnd = ContinuousEngine(cfg, params, n_slots=2, max_seq=64)
    return SpaceGroundScheduler(
        sat, gnd,
        schedule=ContactSchedule(contact_duration_s=4.0,
                                 contacts_per_day=8640, seed=3),
        gate=ConfidenceGate("max_prob", 0.6),
        s_per_step=1.0, horizon_s=7200.0, comm_reserve_pages=4, **kw)


def test_sgs_validates_fault_configuration(cfg, params):
    lossy = FaultInjector(FaultPlan(frame_loss_rate=0.5))
    with pytest.raises(ValueError, match="frame_bytes"):
        _sg(cfg, params, faults=lossy)           # lossy but unframed
    crashy = FaultInjector(FaultPlan(crash_at_tick=5))
    with pytest.raises(ValueError, match="checkpoint"):
        _sg(cfg, params, faults=crashy)          # crash but no checkpoints


@pytest.mark.slow
def test_sgs_all_faults_token_exact(cfg, params):
    """The tentpole oracle end-to-end: frame loss + corruption, early
    LOS, spill corruption, and a mid-run crash — the faulted replay's
    final answers are IDENTICAL to the fault-free replay's, every
    injected corruption is detected, the crash is survived once, and
    the satellite drains clean."""
    trace = _sg_trace(cfg)
    rep0 = _sg(cfg, params).run([r.clone() for r in trace])

    inj = FaultInjector(FaultPlan(
        seed=0, frame_loss_rate=0.25, frame_corrupt_rate=0.2,
        truncate_every=3, truncate_frac=0.5,
        spill_corrupt_every=2, crash_at_tick=25))
    sg = _sg(cfg, params, faults=inj, frame_bytes=32,
             link_max_retries=6, checkpoint_every=8)
    rep = sg.run([r.clone() for r in trace])

    t0 = [t for _, t in sorted(rep0.tokens.items())]
    t1 = [t for _, t in sorted(rep.tokens.items())]
    assert len(t0) == len(t1) == len(trace)
    for a, b in zip(t0, t1):
        np.testing.assert_array_equal(a, b)
    assert rep.n_reboots == 1 == inj.n_crashes
    assert rep.undelivered == []
    ls = rep.lane_stats
    detected = (ls["n_corruptions_detected"]
                + sg.sat.store.stats()["n_spill_corruptions_detected"])
    assert detected == inj.n_corruptions_injected
    assert ls["n_silent_corruptions"] == 0
    assert inj.n_windows_truncated > 0
    assert ls["n_retransmits"] > 0
    assert rep.ledger.get("bytes_retransmitted") > 0
    assert abs(ls["frame_bytes_attempted"]
               - (ls["bytes_sent"] + ls["bytes_lost"] + ls["bytes_corrupt"])
               ) < 1e-6
    assert len(sg.sat.store) == 0
    _assert_drained(sg.sat.engine)


@pytest.mark.slow
def test_sgs_crash_only_reboot_resumes_exactly(cfg, params):
    """Crash-only plan (no link faults, unframed lane): the reboot path
    alone must be token-exact and leave ledger item counts undoubled."""
    trace = _sg_trace(cfg, n=4, seed=5)
    rep0 = _sg(cfg, params).run([r.clone() for r in trace])
    inj = FaultInjector(FaultPlan(seed=0, crash_at_tick=15))
    sg = _sg(cfg, params, faults=inj, checkpoint_every=5)
    rep = sg.run([r.clone() for r in trace])
    assert rep.n_reboots == 1
    t0 = [t for _, t in sorted(rep0.tokens.items())]
    t1 = [t for _, t in sorted(rep.tokens.items())]
    for a, b in zip(t0, t1):
        np.testing.assert_array_equal(a, b)
    # post-rollback re-finishes must not double-count ledger items
    assert rep.ledger.get("items_total") == len(trace)
    assert rep0.ledger.get("items_total") == len(trace)
    _assert_drained(sg.sat.engine)
