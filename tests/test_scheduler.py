"""Contact-window preemptive scheduler: preempt-and-resume
token-exactness (the tentpole oracle), pool-exhaustion x preemption
interplay, priority preemption, and the space-ground two-tier replay.

The hypothesis property tests for the scheduler invariants (no page
leak, no double free, no starvation, exact reservation accounting)
live in ``test_property.py``, which guards the optional dependency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tiansuan_pair as TP
from repro.core.gating import ConfidenceGate
from repro.core.link import ContactSchedule, TransmitLane
from repro.models import transformer as T
from repro.serving.batching import Request
from repro.serving.engine import ContinuousEngine
from repro.serving.paging import BlockAllocator, PoolExhausted
from repro.serving.scheduler import (PreemptiveScheduler,
                                     SpaceGroundScheduler)

from helpers import f32_cfg


@pytest.fixture(scope="module")
def cfg():
    return f32_cfg("smollm-360m")


@pytest.fixture(scope="module")
def params(cfg):
    return T.init_params(jax.random.PRNGKey(0), cfg, max_seq=64)


def _prompt(rng, n, vocab):
    return rng.integers(1, vocab, n).astype(np.int32)


def _solo_tokens(cfg, params, prompt, max_new, **engine_kw):
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64, **engine_kw)
    res = eng.run([Request(prompt=prompt.copy(), max_new=max_new)])
    return list(res.values())[0].tokens


def _assert_drained(eng):
    """The page pool must be exactly restored after a full drain."""
    alloc = getattr(eng.slots, "allocator", None)
    if alloc is not None:
        assert alloc.in_use == 0 and alloc.reserved == 0
        assert len(alloc._free) == alloc.n_pages
        assert alloc._free_set == set(alloc._free)


def _preempt_resume_sweep(cfg, params, *, mode, max_new=6, n_slots=2,
                          with_filler=True, **engine_kw):
    """Interrupt a probe at EVERY decode step k, resume, and require the
    exact token stream of an uninterrupted run.  One engine serves the
    whole sweep (drained between iterations) so jit caches stay warm;
    a filler decodes while the probe is swapped out, so resumed pages
    really are re-allocated, not accidentally untouched."""
    rng = np.random.default_rng(42)
    prompt = _prompt(rng, 7, cfg.vocab_size)
    filler_prompt = _prompt(rng, 5, cfg.vocab_size)
    want = _solo_tokens(cfg, params, prompt, max_new, **engine_kw)

    eng = ContinuousEngine(cfg, params, n_slots=n_slots, max_seq=64,
                           **engine_kw)
    sched = PreemptiveScheduler(eng, preempt_mode=mode)
    # k = 0 preempts straight after admission (only the prefill token
    # exists); k = max_new - 2 preempts one step before the finish line
    for k in range(max_new - 1):
        probe = Request(prompt=prompt.copy(), max_new=max_new)
        sched.submit(probe)
        sched.step(decode=False)       # pure clock tick keeps runs aligned
        sched._admit_by_priority()     # admission without a decode step
        for _ in range(k):
            sched.step()
        (slot,) = [s for s in eng.slots.active_slots()
                   if eng.slots.states[s].request.rid == probe.rid]
        sched.preempt(slot)
        if with_filler:                # pool churn while the probe is out
            sched.submit(Request(prompt=filler_prompt.copy(), max_new=3))
            sched.step()
            sched.step()
        else:
            sched.step(decode=False)
        res = sched.run()
        np.testing.assert_array_equal(res[probe.rid].tokens, want)
        assert res[probe.rid].n_preemptions == 1
        _assert_drained(eng)
    assert sched.n_resumes == sched.n_preemptions == max_new - 1


# ---------------------------------------------------------------------------
# preempt-then-resume token-exactness
# ---------------------------------------------------------------------------

def test_preempt_resume_every_step_spill(cfg, params):
    _preempt_resume_sweep(cfg, params, mode="spill")


def test_preempt_resume_every_step_resident(cfg, params):
    _preempt_resume_sweep(cfg, params, mode="resident")


def test_preempt_resume_contiguous_layout(cfg, params):
    _preempt_resume_sweep(cfg, params, mode="spill",
                          kv_layout="contiguous")


def test_contiguous_resident_coerces_to_spill(cfg, params):
    """The contiguous layout has no resident identity (the row may be
    regrafted while swapped) — resident preemption must degrade to a
    spill instead of resuming stale KV."""
    eng = ContinuousEngine(cfg, params, n_slots=1, max_seq=64,
                           kv_layout="contiguous")
    sched = PreemptiveScheduler(eng, preempt_mode="resident")
    req = Request(prompt=np.arange(1, 8, dtype=np.int32), max_new=6)
    sched.submit(req)
    sched.step()
    sched.preempt(eng.slots.active_slots()[0])
    assert sched.swapped[req.rid].spilled      # coerced
    assert sched.n_spills == 1
    res = sched.run()
    assert len(res[req.rid].tokens) == 6


@pytest.mark.slow   # compiles prefill+decode per arch
@pytest.mark.parametrize("arch", [
    "qwen3-moe-30b-a3b",    # moe routing through resumed pages
    "deepseek-v3-671b",     # MLA latent cache preempted/resumed
])
def test_preempt_resume_every_step_all_families(arch):
    fam_cfg = f32_cfg(arch)
    fam_params = T.init_params(jax.random.PRNGKey(0), fam_cfg, max_seq=64)
    _preempt_resume_sweep(fam_cfg, fam_params, mode="spill", max_new=5)


def test_extract_graft_paged_roundtrip(cfg, params):
    """extract_paged_cache o graft_paged_cache is bit-exact, including
    relocation to a different set of pages."""
    eng = ContinuousEngine(cfg, params, n_slots=1, max_seq=64,
                           kv_layout="paged", page_size=8)
    req = Request(prompt=np.arange(1, 13, dtype=np.int32), max_new=4)
    eng.submit(req)
    eng.step()
    (slot,) = eng.slots.active_slots()
    src = eng.slots.states[slot].pages
    snap = T.extract_paged_cache(eng.slots.cache,
                                 jnp.asarray(src, jnp.int32))
    # scatter into different page ids and gather back
    dst = [p + 3 for p in src]
    assert set(dst).isdisjoint(src)
    relocated = T.graft_paged_cache(eng.slots.cache, snap,
                                    jnp.asarray(dst, jnp.int32))
    back = T.extract_paged_cache(relocated, jnp.asarray(dst, jnp.int32))
    for a, b in zip(jax.tree.leaves(snap), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_extract_graft_since_reassembles_base_plus_delta(cfg, params):
    """The KV-delta spill format at the cache level: a base snapshot
    plus a ``since``-delta grafted over fresh pages reassemble the
    exact live cache."""
    eng = ContinuousEngine(cfg, params, n_slots=1, max_seq=64,
                           kv_layout="paged", page_size=8, pool_pages=8)
    req = Request(prompt=np.arange(1, 13, dtype=np.int32), max_new=8)
    eng.submit(req)
    eng.step()
    (slot,) = eng.slots.active_slots()
    base_pages = list(eng.slots.states[slot].pages)       # 2 pages
    base = jax.device_get(T.extract_paged_cache(
        eng.slots.cache, jnp.asarray(base_pages, jnp.int32)))
    while len(eng.slots.states[slot].pages) < 3:          # grow + dirty
        eng.step()
    pages = list(eng.slots.states[slot].pages)
    full = T.extract_paged_cache(eng.slots.cache,
                                 jnp.asarray(pages, jnp.int32))
    # page 0 was never rewritten after the base snapshot: ship pages 1+
    delta = T.extract_paged_cache(eng.slots.cache,
                                  jnp.asarray(pages, jnp.int32), 1)
    for d, f in zip(jax.tree.leaves(delta), jax.tree.leaves(full)):
        np.testing.assert_array_equal(np.asarray(d),
                                      np.asarray(f)[:, :, 8:])
    # reassemble into disjoint destination pages: base first, delta over
    dst = [p + 4 for p in pages]
    assert set(dst).isdisjoint(pages)
    pool = T.graft_paged_cache(eng.slots.cache, base,
                               jnp.asarray(dst[:2], jnp.int32))
    pool = T.graft_paged_cache(pool, delta, jnp.asarray(dst, jnp.int32), 1)
    back = T.extract_paged_cache(pool, jnp.asarray(dst, jnp.int32))
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# KV-delta spills: re-preemption across windows
# ---------------------------------------------------------------------------

def test_re_preemption_second_spill_is_delta_only(cfg, params):
    """preempt -> resume -> preempt again: the second spill ships only
    the pages dirtied since the first (strictly fewer bytes than a full
    spill), and the twice-resumed stream stays token-exact."""
    prompt = np.arange(1, 13, dtype=np.int32)
    want = _solo_tokens(cfg, params, prompt, 20,
                        kv_layout="paged", page_size=8)
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                           kv_layout="paged", page_size=8)
    sched = PreemptiveScheduler(eng)
    probe = Request(prompt=prompt.copy(), max_new=20)
    sched.submit(probe)
    for _ in range(3):
        sched.step()
    (slot,) = eng.slots.active_slots()
    sched.preempt(slot)
    first = sched.stats()
    assert first["n_spills"] == 1 and first["n_delta_spills"] == 0
    assert first["spill_bytes"] == first["spill_bytes_full_equiv"] > 0
    sched.step(decode=False)           # one idle window tick
    for _ in range(4):
        sched.step()                   # resume + decode past the watermark
    (slot,) = eng.slots.active_slots()
    sched.preempt(slot)                # second spill: delta only
    second = sched.stats()
    assert second["n_delta_spills"] == 1
    delta_bytes = second["spill_bytes"] - first["spill_bytes"]
    full_bytes = (second["spill_bytes_full_equiv"]
                  - first["spill_bytes_full_equiv"])
    assert 0 < delta_bytes < full_bytes
    res = sched.run()
    np.testing.assert_array_equal(res[probe.rid].tokens, want)
    assert res[probe.rid].n_preemptions == 2
    assert len(sched.store) == 0       # spill history dropped at finish
    _assert_drained(eng)


def test_re_preempt_every_step_stays_exact(cfg, params):
    """Re-preemption sweep: spill at every step k, resume, spill again
    two steps later — every doubly-interrupted stream matches the
    uninterrupted run, and every second spill is a delta."""
    max_new = 8
    rng = np.random.default_rng(11)
    prompt = _prompt(rng, 9, cfg.vocab_size)
    want = _solo_tokens(cfg, params, prompt, max_new,
                        kv_layout="paged", page_size=8)
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                           kv_layout="paged", page_size=8)
    sched = PreemptiveScheduler(eng)
    n_probes = max_new - 3
    for k in range(n_probes):
        probe = Request(prompt=prompt.copy(), max_new=max_new)
        sched.submit(probe)
        sched.step(decode=False)       # pure clock tick keeps runs aligned
        sched._admit_by_priority()     # admission without a decode step
        for _ in range(k):
            sched.step()
        (slot,) = [s for s in eng.slots.active_slots()
                   if eng.slots.states[s].request.rid == probe.rid]
        sched.preempt(slot)            # spill 1: full
        sched.step()                   # resume + decode
        sched.step()
        (slot,) = [s for s in eng.slots.active_slots()
                   if eng.slots.states[s].request.rid == probe.rid]
        sched.preempt(slot)            # spill 2: delta
        res = sched.run()
        np.testing.assert_array_equal(res[probe.rid].tokens, want)
        assert res[probe.rid].n_preemptions == 2
        _assert_drained(eng)
    stats = sched.stats()
    assert stats["n_spills"] == 2 * n_probes
    assert stats["n_delta_spills"] == n_probes
    assert stats["spill_bytes"] < stats["spill_bytes_full_equiv"]
    assert len(sched.store) == 0


def test_delta_spill_disabled_keeps_exactness(cfg, params):
    """delta_spill=False falls back to one-shot full snapshots (no host
    store) and stays token-exact across re-preemption."""
    prompt = np.arange(1, 13, dtype=np.int32)
    want = _solo_tokens(cfg, params, prompt, 12,
                        kv_layout="paged", page_size=8)
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                           kv_layout="paged", page_size=8)
    sched = PreemptiveScheduler(eng, delta_spill=False)
    assert sched.store is None
    probe = Request(prompt=prompt.copy(), max_new=12)
    sched.submit(probe)
    for _ in range(2):
        sched.step()
    sched.preempt(eng.slots.active_slots()[0])
    sched.step()
    sched.step()
    sched.preempt(eng.slots.active_slots()[0])
    res = sched.run()
    np.testing.assert_array_equal(res[probe.rid].tokens, want)
    assert sched.stats()["spill_bytes"] == 0      # nothing metered
    _assert_drained(eng)


# ---------------------------------------------------------------------------
# transmit lane + comm-reserve page hold (overlapped contact pipeline)
# ---------------------------------------------------------------------------

def test_transmit_lane_incremental_drain():
    """FIFO payloads drain against per-tick budgets; a payload larger
    than one tick's budget carries partial progress across ticks."""
    lane = TransmitLane()
    lane.enqueue("a", 100)
    lane.enqueue("b", 50)
    lane.enqueue("c", 300)
    assert lane.tick(120) == ["a"]     # 20 spare bytes start on b
    assert lane.pending_bytes() == 330
    assert lane.tick(30) == ["b"]      # b's carryover completes exactly
    assert lane.tick(100) == []        # c mid-flight
    assert lane.tick(200) == ["c"]
    assert lane.bytes_sent == 450
    assert lane.n_completed == 3 and len(lane) == 0
    lane.enqueue("d", 10)
    assert lane.clear() == ["d"] and len(lane) == 0


def test_transmit_lane_zero_budget_tick():
    """A zero-byte tick (a pass tick with no link margin) delivers
    nothing, sends nothing, and is not a partial-progress tick."""
    lane = TransmitLane()
    lane.enqueue("a", 100)
    assert lane.tick(0) == []
    assert lane.bytes_sent == 0 and lane.n_partial_ticks == 0
    assert lane.pending_bytes() == 100


def test_transmit_lane_clear_mid_partial_keeps_bytes_sent():
    """clear() mid-payload returns the pending item but already-sent
    bytes stay metered — the link really transmitted them."""
    lane = TransmitLane()
    lane.enqueue("a", 100)
    assert lane.tick(30) == []
    assert lane.bytes_sent == 30
    assert lane.clear() == ["a"]
    assert lane.bytes_sent == 30 and len(lane) == 0
    assert lane.n_completed == 0


def test_transmit_lane_partial_ticks_across_window_boundary():
    """Partial-progress accounting spans a window gap: two partial
    ticks then completion, with no double count for the idle gap."""
    lane = TransmitLane()
    lane.enqueue("a", 100)
    assert lane.tick(40) == [] and lane.tick(40) == []
    assert lane.n_partial_ticks == 2           # the gap itself: no tick
    assert lane.tick(40) == ["a"]
    assert lane.n_partial_ticks == 2 and lane.n_completed == 1
    assert lane.bytes_sent == 100


def test_contact_windows_dense_schedule_stays_disjoint():
    """Regression: a contact_duration_s LONGER than the orbital period
    (negative slack) must still yield ordered, disjoint, positive
    windows instead of overlapping ones."""
    sched = ContactSchedule(contact_duration_s=20_000.0,
                            contacts_per_day=6, seed=0)
    wins = sched.windows(86_400.0)
    assert wins, "dense schedule produced no windows"
    for a, b in wins:
        assert b > a
    for (_, b1), (a2, _) in zip(wins, wins[1:]):
        assert b1 <= a2                        # clamped: no overlap
    assert sched.downlink_capacity_bytes(86_400.0) > 0


def test_hold_pages_spills_only_what_the_reserve_needs(cfg, params):
    """The comm reserve spills the fewest sequences that cover it (the
    largest block table first); everything else keeps decoding through
    the window and the spilled victim resumes token-exactly after
    release."""
    prompt_big = np.arange(1, 17, dtype=np.int32)
    want_big = _solo_tokens(cfg, params, prompt_big, 16,
                            kv_layout="paged", page_size=8)
    eng = ContinuousEngine(cfg, params, n_slots=3, max_seq=64,
                           kv_layout="paged", page_size=8, pool_pages=8)
    sched = PreemptiveScheduler(eng)
    big = Request(prompt=prompt_big.copy(), max_new=16)     # 4-page budget
    small = Request(prompt=np.arange(1, 9, dtype=np.int32),
                    max_new=8)                              # 2-page budget
    sched.submit(big)
    sched.submit(small)
    sched.step()
    assert len(eng.slots.active_slots()) == 2
    held = sched.hold_pages(4)         # available()==2: must spill ONE
    assert held == 4
    assert big.rid in sched.swapped    # largest table picked
    assert small.rid not in sched.swapped
    assert sched.hold_pages(4) == 4    # idempotent within a pass
    for _ in range(3):
        sched.step()                   # small keeps decoding in-window
        assert {eng.slots.states[s].request.rid
                for s in eng.slots.active_slots()} == {small.rid}
    sched.release_hold()
    res = sched.run()
    np.testing.assert_array_equal(res[big.rid].tokens, want_big)
    assert len(res[small.rid].tokens) == 8
    assert res[big.rid].n_preemptions == 1
    _assert_drained(eng)


def test_hold_pages_contiguous_layout_is_noop(cfg, params):
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                           kv_layout="contiguous")
    sched = PreemptiveScheduler(eng)
    assert sched.hold_pages(4) == 0    # no pool: nothing to hold
    sched.release_hold()               # must not raise


# ---------------------------------------------------------------------------
# pool exhaustion x preemption
# ---------------------------------------------------------------------------

def test_preempt_frees_pages_for_waiting_request(cfg, params):
    """With the pool full, spilling an active sequence must make its
    pages claimable by the queued request, and the spilled sequence must
    re-admit and finish afterwards — no deadlock, no leak."""
    # pool of 4 pages, every request needs 2: two run, the third waits
    eng = ContinuousEngine(cfg, params, n_slots=3, max_seq=64,
                           kv_layout="paged", page_size=16, pool_pages=4)
    sched = PreemptiveScheduler(eng)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=_prompt(rng, 16, cfg.vocab_size), max_new=9)
            for _ in range(3)]
    for r in reqs:
        sched.submit(r)
    sched.step()
    assert len(eng.slots.active_slots()) == 2
    assert eng.slots.allocator.available() == 0
    waiting = reqs[2]
    assert waiting.rid not in eng.results

    victim_slot = eng.slots.active_slots()[0]
    victim_rid = eng.slots.states[victim_slot].request.rid
    sched.preempt(victim_slot)                  # spill: pages reclaimable
    assert eng.slots.allocator.can_reserve(2)
    sched.step()                                # waiting request admitted
    active_rids = {eng.slots.states[s].request.rid
                   for s in eng.slots.active_slots()}
    assert waiting.rid in active_rids
    assert victim_rid in sched.swapped

    results = sched.run()                       # victim resumes, all finish
    assert sorted(results) == sorted(r.rid for r in reqs)
    for r in reqs:
        assert len(results[r.rid].tokens) == r.max_new
    assert results[victim_rid].n_preemptions == 1
    _assert_drained(eng)


def test_preempted_solo_matches_uninterrupted_under_pool_churn(cfg, params):
    """The spilled sequence's final tokens are those of an uninterrupted
    run even though its pages were recycled by another request."""
    want = _solo_tokens(cfg, params,
                        np.arange(1, 17, dtype=np.int32), 9,
                        kv_layout="paged", page_size=16)
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                           kv_layout="paged", page_size=16, pool_pages=2)
    sched = PreemptiveScheduler(eng)
    probe = Request(prompt=np.arange(1, 17, dtype=np.int32), max_new=9)
    churn = Request(prompt=np.arange(1, 17, dtype=np.int32), max_new=5)
    sched.submit(probe)
    sched.step()
    sched.step()
    sched.preempt(eng.slots.active_slots()[0])
    sched.submit(churn)                         # takes the SAME two pages
    res = sched.run()
    np.testing.assert_array_equal(res[probe.rid].tokens, want)
    _assert_drained(eng)


def test_release_already_freed_table_raises():
    """Regression: releasing a block table twice must fail loudly
    instead of corrupting the pool (a double-released page would later
    be handed to two live sequences)."""
    a = BlockAllocator(6)
    a.reserve(4)
    table = a.alloc(4)
    a.release(table)
    with pytest.raises(PoolExhausted):
        a.release(table)
    # the failed release must not have corrupted the free list
    assert a.in_use == 0 and len(a._free) == 6
    a.reserve(6)
    assert sorted(a.alloc(6)) == [1, 2, 3, 4, 5, 6]


def test_double_evict_raises(cfg, params):
    eng = ContinuousEngine(cfg, params, n_slots=1, max_seq=64)
    eng.submit(Request(prompt=np.arange(1, 5, dtype=np.int32), max_new=4))
    eng.step()
    (slot,) = eng.slots.active_slots()
    st = eng.slots.states[slot]
    eng.slots.evict(slot)
    eng.slots.states[slot] = st                 # simulate bookkeeping bug
    with pytest.raises(PoolExhausted):
        eng.slots.evict(slot)


# ---------------------------------------------------------------------------
# priority scheduling
# ---------------------------------------------------------------------------

def test_priority_arrival_preempts_lower_priority(cfg, params):
    """A high-priority arrival blocked on pages spills the weakest
    active sequence, runs to completion first, and the victim still
    finishes with its uninterrupted token stream."""
    prompt = np.arange(1, 17, dtype=np.int32)
    want_victim = _solo_tokens(cfg, params, prompt, 9,
                               kv_layout="paged", page_size=16)
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                           kv_layout="paged", page_size=16, pool_pages=2)
    sched = PreemptiveScheduler(eng)
    low = Request(prompt=prompt.copy(), max_new=9, priority=0)
    high = Request(prompt=prompt.copy(), max_new=3, priority=5)
    sched.submit(low)
    sched.step()                                # low occupies the whole pool
    assert eng.slots.allocator.available() == 0
    sched.submit(high)
    sched.step()                                # high preempts low
    assert low.rid in sched.swapped
    results = sched.run()
    assert results[high.rid].finished_step < results[low.rid].finished_step
    assert results[low.rid].n_preemptions == 1
    np.testing.assert_array_equal(results[low.rid].tokens, want_victim)
    _assert_drained(eng)


def test_equal_priority_never_preempts(cfg, params):
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                           kv_layout="paged", page_size=16, pool_pages=2)
    sched = PreemptiveScheduler(eng)
    first = Request(prompt=np.arange(1, 17, dtype=np.int32), max_new=6,
                    priority=1)
    second = Request(prompt=np.arange(1, 17, dtype=np.int32), max_new=6,
                     priority=1)
    sched.submit(first)
    sched.submit(second)
    results = sched.run()
    assert sched.n_preemptions == 0             # FIFO within a priority
    assert results[first.rid].finished_step <= results[second.rid].finished_step


def test_preempt_mode_validation(cfg, params):
    eng = ContinuousEngine(cfg, params, n_slots=1, max_seq=64)
    with pytest.raises(ValueError):
        PreemptiveScheduler(eng, preempt_mode="swap-to-tape")


def test_logits_last_present_even_for_prefill_finish(cfg, params):
    """Regression: the paged place() must carry last_logits through its
    state rebuild — a max_new==1 request finishes at admission and the
    confidence gate needs its logits.  For any request, logits_last is
    the distribution the final token was drawn from."""
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64)
    assert eng.kv_layout == "paged"
    one = Request(prompt=np.arange(1, 9, dtype=np.int32), max_new=1)
    many = Request(prompt=np.arange(1, 9, dtype=np.int32), max_new=5)
    results = eng.run([one, many])
    for req in (one, many):
        res = results[req.rid]
        assert res.logits_last is not None
        assert int(np.argmax(res.logits_last)) == int(res.tokens[-1])


def test_resident_swap_outranks_lower_priority_active(cfg, params):
    """Regression: a blocked RESIDENT swap entry needs only a slot (its
    pages are still committed), so the priority-preemption feasibility
    check must use need=0, not its full page budget — otherwise the
    high-priority sequence waits behind lower-priority work."""
    want = _solo_tokens(cfg, params, np.arange(1, 17, dtype=np.int32), 8,
                        kv_layout="paged", page_size=16)
    eng = ContinuousEngine(cfg, params, n_slots=1, max_seq=64,
                           kv_layout="paged", page_size=16, pool_pages=3)
    sched = PreemptiveScheduler(eng)
    high = Request(prompt=np.arange(1, 17, dtype=np.int32), max_new=8,
                   priority=5)
    sched.submit(high)
    sched.step()
    sched.preempt(0, "resident")       # pages stay committed (2 of 3)
    low = Request(prompt=np.arange(1, 17, dtype=np.int32), max_new=8,
                  priority=0)
    sched.submit(low)
    sched.step()                       # high must reclaim the slot at once
    assert eng.slots.states[0].request.rid == high.rid
    results = sched.run()
    assert results[high.rid].finished_step < results[low.rid].finished_step
    np.testing.assert_array_equal(results[high.rid].tokens, want)
    _assert_drained(eng)


def test_queue_head_of_line_blocks_smaller_later_arrivals(cfg, params):
    """Regression: within a priority class the queue keeps the engine's
    FIFO head-of-line discipline — a later, smaller request must not
    jump a head blocked on pages (that backfill can starve the head
    under a steady arrival stream)."""
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                           kv_layout="paged", page_size=16, pool_pages=4)
    sched = PreemptiveScheduler(eng)
    running = Request(prompt=np.arange(1, 17, dtype=np.int32), max_new=9)
    sched.submit(running)
    sched.step()                       # holds 2 of 4 pages
    big = Request(prompt=np.arange(1, 33, dtype=np.int32), max_new=16)
    small = Request(prompt=np.arange(1, 9, dtype=np.int32), max_new=2)
    sched.submit(big)                  # head: needs 3 pages, only 2 free
    sched.submit(small)                # would fit, but must wait for big
    sched.step()
    assert {eng.slots.states[s].request.rid
            for s in eng.slots.active_slots()} == {running.rid}
    results = sched.run()
    assert results[big.rid].admitted_step <= results[small.rid].admitted_step
    assert len(results[big.rid].tokens) == 16
    _assert_drained(eng)


def test_contiguous_blocked_swap_entry_no_crash(cfg, params):
    """Regression: a swapped-out CONTIGUOUS sequence waiting behind a
    full slot table must not crash the priority pass (contiguous slot
    states carry no page budget) — and must still finish exactly."""
    want = _solo_tokens(cfg, params, np.arange(1, 8, dtype=np.int32), 7,
                        kv_layout="contiguous")
    eng = ContinuousEngine(cfg, params, n_slots=1, max_seq=64,
                           kv_layout="contiguous")
    sched = PreemptiveScheduler(eng)
    probe = Request(prompt=np.arange(1, 8, dtype=np.int32), max_new=7)
    sched.submit(probe)
    sched.step()
    sched.preempt(0)                   # coerced to spill
    other = Request(prompt=np.arange(1, 6, dtype=np.int32), max_new=4)
    sched.submit(other)
    results = sched.run()              # probe waits, resumes, finishes
    np.testing.assert_array_equal(results[probe.rid].tokens, want)
    assert len(results[other.rid].tokens) == 4


def test_blocked_spilled_head_vetoes_fresh_arrivals(cfg, params):
    """Regression: a spilled sequence blocked on pages must not be
    starved by same-priority fresh arrivals — the swap head vetoes
    page-consuming queue admissions while it cannot re-reserve."""
    eng = ContinuousEngine(cfg, params, n_slots=3, max_seq=64,
                           kv_layout="paged", page_size=16, pool_pages=5)
    sched = PreemptiveScheduler(eng)
    # a: 2 pages, long-running; b: 3 pages (the preemptee)
    a = Request(prompt=np.arange(1, 17, dtype=np.int32), max_new=17)
    b = Request(prompt=np.arange(1, 33, dtype=np.int32), max_new=16)
    sched.submit(a)
    sched.submit(b)
    sched.step()                       # pool exhausted: 2 + 3 of 5
    (b_slot,) = [s for s in eng.slots.active_slots()
                 if eng.slots.states[s].request.rid == b.rid]
    sched.preempt(b_slot)              # spill: 3 pages free again
    c = Request(prompt=np.arange(1, 9, dtype=np.int32), max_new=9)
    sched.submit(c)                    # 1 page; arrival beats b's resume
    sched.step()
    assert b.rid in sched.swapped      # c took a page: b blocked (needs 3)
    d = Request(prompt=np.arange(1, 9, dtype=np.int32), max_new=2)
    sched.submit(d)                    # 1 page would fit — must be vetoed
    sched.step()
    active = {eng.slots.states[s].request.rid
              for s in eng.slots.active_slots()}
    assert d.rid not in active and b.rid in sched.swapped
    results = sched.run()              # c drains -> b resumes -> d runs
    for req, n in ((a, 17), (b, 16), (c, 9), (d, 2)):
        assert len(results[req.rid].tokens) == n
    assert results[b.rid].n_preemptions == 1
    _assert_drained(eng)


# ---------------------------------------------------------------------------
# space-ground tiering
# ---------------------------------------------------------------------------

def test_step_windows_skips_horizon_clipped_passes():
    """Regression: a pass whose start lies beyond the horizon is clamped
    by ``windows`` into an inverted (b <= a) tuple — ``step_windows``
    must drop it rather than fabricate a post-horizon 1-tick window."""
    for seed in range(8):
        sched = ContactSchedule(contact_duration_s=480.0,
                                contacts_per_day=6, seed=seed)
        horizon = 7200.0
        for lo, hi in sched.step_windows(1.0, horizon):
            assert lo < hi
            assert lo < horizon          # never starts past the horizon


def test_space_ground_no_window_records_undelivered(cfg, params):
    """With no contact window inside the horizon the satellite still
    answers everything, but the downlink backlog is recorded as
    undelivered instead of silently dropped, and nothing reaches the
    ground tier."""
    trace = [r.clone() for r in _sg_trace(cfg, n=3)]
    sat = ContinuousEngine(cfg, params, n_slots=2, max_seq=64)
    gnd = ContinuousEngine(cfg, params, n_slots=2, max_seq=64)
    sg = SpaceGroundScheduler(
        sat, gnd,
        schedule=ContactSchedule(contact_duration_s=480.0,
                                 contacts_per_day=6, seed=0),
        gate=ConfidenceGate("max_prob", 2.0),     # would escalate all
        s_per_step=1.0, horizon_s=100.0)          # ...but no pass fits
    rep = sg.run(trace)
    assert rep.windows == []
    assert sorted(rep.undelivered) == sorted(r.rid for r in trace)
    assert not rep.escalated and not rep.ground_results
    for r in trace:                    # satellite answers still stand
        assert len(rep.tokens[r.rid]) == r.max_new
    assert rep.ledger.get("bytes_downlinked") == 0

def _sg_setup(cfg, params, *, threshold, seed=1, **kw):
    sat = ContinuousEngine(cfg, params, n_slots=2, max_seq=64)
    gnd = ContinuousEngine(cfg, params, n_slots=2, max_seq=64)
    schedule = ContactSchedule(contact_duration_s=8.0,
                               contacts_per_day=2400, seed=seed)
    return SpaceGroundScheduler(
        sat, gnd, schedule=schedule,
        gate=ConfidenceGate("max_prob", threshold),
        s_per_step=1.0, horizon_s=7200.0, **kw)


def _sg_trace(cfg, n=6, seed=8):
    rng = np.random.default_rng(seed)
    return [Request(prompt=_prompt(rng, int(rng.integers(4, 12)),
                                   cfg.vocab_size),
                    max_new=int(rng.integers(4, 10)),
                    arrival_t=float(i * 2))
            for i in range(n)]


def test_space_ground_windows_preempt_and_stay_exact(cfg, params):
    """Stop-the-world schedule (overlap=False, PR 3 semantics): contact
    windows preempt satellite decode mid-flight, yet every satellite
    answer equals its uninterrupted run — and nothing is escalated
    below threshold 0 (satellite answers stand)."""
    trace = _sg_trace(cfg)
    sg = _sg_setup(cfg, params, threshold=-1.0,   # never escalate
                   overlap=False)
    rep = sg.run([r.clone() for r in trace])
    assert rep.n_preemptions >= 1                 # windows actually hit
    assert rep.decode_steps_in_window == 0        # compute fully yielded
    assert not rep.escalated and not rep.ground_results
    assert sorted(rep.tokens) == sorted(rep.sat_results)
    # token-exact vs an uninterrupted satellite-only engine
    ref_eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64)
    ref = ref_eng.run([r.clone() for r in trace])
    for (rid_a, res_a), (rid_b, toks_b) in zip(
            sorted(ref.items()), sorted(rep.tokens.items())):
        np.testing.assert_array_equal(toks_b, res_a.tokens)
    assert rep.ledger.get("energy_compute_j") > 0
    _assert_drained(sg.sat.engine)


def test_space_ground_overlap_decodes_through_passes(cfg, params):
    """The overlapped pipeline (default): satellite decode continues
    through contact windows, answers stay token-exact with the
    uninterrupted run, and the replay drains no later than the
    stop-the-world schedule on the same windows."""
    trace = _sg_trace(cfg)
    sg_ov = _sg_setup(cfg, params, threshold=-1.0)
    rep_ov = sg_ov.run([r.clone() for r in trace])
    sg_stw = _sg_setup(cfg, params, threshold=-1.0, overlap=False)
    rep_stw = sg_stw.run([r.clone() for r in trace])
    assert rep_ov.decode_steps_in_window > 0      # compute lane ran in-pass
    assert rep_stw.decode_steps_in_window == 0
    assert sg_ov.sat.clock <= sg_stw.sat.clock    # overlap drains no later
    ref_eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64)
    ref = ref_eng.run([r.clone() for r in trace])
    for (_, res_a), (_, toks_b) in zip(
            sorted(ref.items()), sorted(rep_ov.tokens.items())):
        np.testing.assert_array_equal(toks_b, res_a.tokens)
    s = rep_ov.sat_stats
    assert s["n_resumes"] == s["n_preemptions"]
    assert s["spill_bytes"] <= s["spill_bytes_full_equiv"]
    assert len(sg_ov.sat.store) == 0              # spill history cleaned up
    assert sg_ov.sat.held_pages == 0              # reserve returned
    _assert_drained(sg_ov.sat.engine)


def test_space_ground_overlap_comm_reserve_forces_delta_spills(cfg, params):
    """A contended pool + dense passes: the comm reserve must spill the
    same long sequence across several windows; re-spills are deltas and
    every answer still matches the uninterrupted run."""
    rng = np.random.default_rng(5)
    trace = [Request(prompt=_prompt(rng, 12, cfg.vocab_size),
                     max_new=18, arrival_t=float(i)) for i in range(3)]
    ref_eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                               page_size=8, pool_pages=9)
    ref = ref_eng.run([r.clone() for r in trace])

    sat = ContinuousEngine(cfg, params, n_slots=2, max_seq=64,
                           page_size=8, pool_pages=9)
    gnd = ContinuousEngine(cfg, params, n_slots=2, max_seq=64)
    sg = SpaceGroundScheduler(
        sat, gnd,
        schedule=ContactSchedule(contact_duration_s=4.0,
                                 contacts_per_day=8640, seed=3),
        gate=ConfidenceGate("max_prob", -1.0),    # never escalate
        s_per_step=1.0, horizon_s=7200.0,
        comm_reserve_pages=4)
    rep = sg.run([r.clone() for r in trace])
    s = rep.sat_stats
    assert rep.n_preemptions >= 2                 # reserve forced spills
    assert s["n_delta_spills"] >= 1               # ...and re-spills deltas
    assert s["spill_bytes"] < s["spill_bytes_full_equiv"]
    for (_, res_a), (_, toks_b) in zip(
            sorted(ref.items()), sorted(rep.tokens.items())):
        np.testing.assert_array_equal(toks_b, res_a.tokens)
    _assert_drained(sat)


def test_space_ground_escalation_routes_to_ground_tier(cfg, params):
    """Threshold above 1.0 escalates everything: the ground tier
    re-answers every request during contact windows and the ledger
    accounts raw-escalation bytes + comm energy."""
    trace = [r.clone() for r in _sg_trace(cfg)]
    sg = _sg_setup(cfg, params, threshold=2.0)    # always escalate
    rep = sg.run(trace)
    assert sorted(rep.escalated) == sorted(r.rid for r in trace)
    assert not rep.undelivered
    assert sorted(rep.ground_results) == sorted(r.rid for r in trace)
    for rid in rep.escalated:
        np.testing.assert_array_equal(rep.tokens[rid],
                                      rep.ground_results[rid].tokens)
        assert len(rep.tokens[rid]) == len(rep.sat_results[rid].tokens)
    s = rep.ledger.summary()
    assert s["escalation_rate"] == 1.0
    assert s["bytes_raw_escalated"] > 0 and s["energy_comm_j"] > 0
    assert s["downlink_s"] > 0
    _assert_drained(sg.sat.engine)


@pytest.mark.slow   # compiles the full onboard + ground tiansuan pair
def test_space_ground_tiansuan_pair_end_to_end():
    onboard = TP.ONBOARD.with_(param_dtype="float32",
                               activation_dtype="float32")
    ground = TP.GROUND.with_(param_dtype="float32",
                             activation_dtype="float32")
    sat_p = T.init_params(jax.random.PRNGKey(0), onboard, max_seq=64)
    gnd_p = T.init_params(jax.random.PRNGKey(1), ground, max_seq=64)
    rng = np.random.default_rng(3)
    trace = [Request(prompt=_prompt(rng, 8, onboard.vocab_size), max_new=6,
                     arrival_t=float(2 * i)) for i in range(4)]
    sat = ContinuousEngine(onboard, sat_p, n_slots=2, max_seq=64)
    gnd = ContinuousEngine(ground, gnd_p, n_slots=2, max_seq=64)
    sg = SpaceGroundScheduler(
        sat, gnd,
        schedule=ContactSchedule(contact_duration_s=8.0,
                                 contacts_per_day=2400, seed=2),
        gate=ConfidenceGate(TP.CASCADE["confidence_metric"],
                            TP.SCHEDULER["escalate_threshold"]),
        s_per_step=1.0, horizon_s=7200.0)
    rep = sg.run(trace)
    assert len(rep.tokens) == len(trace)
    assert not rep.undelivered
    for rid in rep.escalated:                   # ground answered these
        assert rid in rep.ground_results
    _assert_drained(sat)
    _assert_drained(gnd)


def test_escalated_requests_carry_downlink_arrival(cfg, params):
    """classify() used to hand-build the escalated ground Request and
    silently drop arrival provenance (every escalation arrived at
    t=0.0).  The escalated clone must reach the ground tier stamped
    with its downlink tick — nondecreasing across escalations, so
    ground admission order provably matches downlink order — with the
    source request's priority preserved."""
    trace = [r.clone() for r in _sg_trace(cfg)]
    for i, r in enumerate(trace):
        r.priority = i % 2                        # mixed priorities
    sg = _sg_setup(cfg, params, threshold=2.0)    # always escalate
    seen = []                                     # (arrival_t, priority)
    orig = sg.ground.submit
    def spy(req):
        seen.append((req.arrival_t, req.priority))
        return orig(req)
    sg.ground.submit = spy
    rep = sg.run(trace)
    assert len(seen) == len(trace)                # everything escalated
    arrivals = [a for a, _ in seen]
    assert arrivals == sorted(arrivals)           # downlink order kept
    assert any(a > 0.0 for a in arrivals)         # provenance not erased
    # the i-th ground submission is the i-th downlinked escalation
    prio = {r.rid: r.priority for r in trace}
    assert [p for _, p in seen] == [prio[rid] for rid in rep.escalated]
    _assert_drained(sg.sat.engine)
    _assert_drained(sg.ground)


def test_speculative_escalation_ships_drafts_token_exactly(cfg, params):
    """``speculative=True`` reroutes escalations through draft-id
    downlinks + ground-side batched verification: same final tokens as
    the raw re-decode path on the same trace, strictly fewer escalated
    bytes, and the draft/raw ledger split kept distinct.  Prompts dwarf
    the answers (the deployment shape) — a raw escalation re-uplinks
    the prompt's bytes, a draft escalation ships only the answer's."""
    rng = np.random.default_rng(8)
    trace = [Request(prompt=_prompt(rng, int(rng.integers(24, 40)),
                                    cfg.vocab_size),
                     max_new=int(rng.integers(4, 8)),
                     arrival_t=float(i * 2)) for i in range(4)]
    raw = _sg_setup(cfg, params, threshold=2.0)     # escalate everything
    rep_raw = raw.run([r.clone() for r in trace])
    spec = _sg_setup(cfg, params, threshold=2.0, speculative=True)
    rep_spec = spec.run([r.clone() for r in trace])

    assert len(rep_spec.escalated) == len(rep_raw.escalated) == len(trace)
    # clone() assigns fresh rids: compare streams in admission order
    for a, b in zip([rep_spec.tokens[r] for r in sorted(rep_spec.tokens)],
                    [rep_raw.tokens[r] for r in sorted(rep_raw.tokens)]):
        np.testing.assert_array_equal(a, b)
    led_s, led_r = rep_spec.ledger, rep_raw.ledger
    assert 0 < led_s.get("bytes_draft_escalated") \
        < led_r.get("bytes_raw_escalated")
    assert led_s.get("draft_tokens_shipped") > 0
    assert led_s.get("bytes_raw_escalated") == 0
    assert led_r.get("bytes_draft_escalated") == 0
    # same tiers draft and verify, so the ground engine accepts every
    # shipped draft through real verify passes
    st = rep_spec.spec_stats
    assert st["verify_passes"] > 0
    assert st["drafted"] == st["accepted"] > 0
    assert st["draft_streams_dropped"] == 0
    assert rep_raw.spec_stats == {}
    for sg in (raw, spec):
        _assert_drained(sg.sat.engine)
        _assert_drained(sg.ground)


def test_stats_schema_matches_store_with_and_without_spill(cfg, params):
    """The no-store stats dict is derived from DeltaSpillStore's own
    schema (empty_stats), so the two paths can never drift apart — any
    new store key appears in BOTH or the store's own stats() breaks."""
    from repro.serving.paging import DeltaSpillStore

    eng_d = ContinuousEngine(cfg, params, n_slots=2, max_seq=64)
    with_store = PreemptiveScheduler(eng_d, delta_spill=True).stats()
    eng_n = ContinuousEngine(cfg, params, n_slots=2, max_seq=64)
    no_store = PreemptiveScheduler(eng_n, delta_spill=False).stats()
    assert set(with_store) == set(no_store)
    assert set(DeltaSpillStore.empty_stats()) <= set(no_store)
    # the empty schema IS the live schema, key for key
    assert set(DeltaSpillStore.empty_stats()) == set(
        DeltaSpillStore(8).stats())
