"""Cloud-native orchestration layer: registry liveness, contact-gated
message delivery, deployment + offline-autonomy restore."""
import numpy as np
import pytest

from repro.core.link import ContactSchedule, LinkModel
from repro.orchestration import (AppManifest, Deployer, Message, MessageBus,
                                 MetadataStore, NodeSpec, Registry)


@pytest.fixture
def cluster():
    reg = Registry()
    reg.register(NodeSpec("baoyun", "satellite",
                          contacts=ContactSchedule(seed=3)))
    reg.register(NodeSpec("ground-0", "ground"))
    return reg


def test_registry_reachability_follows_contacts(cluster):
    sat = cluster.get("baoyun")
    wins = sat.contacts.windows(86_400.0)
    inside = 0.5 * (wins[0][0] + wins[0][1])
    outside = wins[0][1] + 30.0
    assert cluster.reachable("baoyun", inside)
    assert not cluster.reachable("baoyun", outside)
    assert cluster.reachable("ground-0", outside)


def test_bus_delivers_only_in_contact_windows(cluster):
    bus = MessageBus(cluster)
    got = []
    bus.subscribe("ground-0", "results", lambda m: got.append(m))
    sat = cluster.get("baoyun")
    win = sat.contacts.windows(86_400.0)[0]
    # send long before the window: must arrive at/after window start
    dt = bus.send("baoyun", "ground-0", "results", {"x": 1},
                  nbytes=10_000, t=0.0)
    assert dt is not None and dt >= win[0]
    bus.advance(win[0] - 1.0)
    assert not got
    bus.advance(dt + 1e-6)
    assert len(got) == 1 and got[0].payload == {"x": 1}


def test_bus_ground_to_ground_instant(cluster):
    cluster.register(NodeSpec("cloud", "ground"))
    bus = MessageBus(cluster)
    got = []
    bus.subscribe("cloud", "sync", lambda m: got.append(m))
    dt = bus.send("ground-0", "cloud", "sync", b"tick", nbytes=64, t=5.0)
    assert dt == 5.0
    bus.advance(5.0)
    assert got


def test_large_transfer_spills_to_next_window(cluster):
    bus = MessageBus(cluster)
    sat = cluster.get("baoyun")
    w0, w1 = sat.contacts.windows(86_400.0)[:2]
    # a transfer bigger than one window's capacity at 40 Mbps
    window_cap = (w0[1] - w0[0]) * 40e6 / 8 * 0.95
    dt = bus.send("baoyun", "ground-0", "bulk", None,
                  nbytes=int(window_cap * 2), t=w0[0])
    assert dt is not None and dt >= w1[0]


def test_deployer_and_offline_restore(tmp_path, cluster):
    store = MetadataStore(str(tmp_path / "meta.json"))
    dep = Deployer(cluster, store)
    made = []
    manifest = AppManifest("onboard-infer", "baoyun",
                           factory=lambda: made.append(1) or "worker-1")
    dep.apply(manifest)
    assert dep.worker("onboard-infer") == "worker-1"
    assert store.actual("onboard-infer") == "running"

    # simulate satellite restart: new deployer, same metadata file
    store2 = MetadataStore(str(tmp_path / "meta.json"))
    store2.record_actual("onboard-infer", "dead")
    dep2 = Deployer(cluster, store2)
    n = dep2.restore({"onboard-infer": lambda: "worker-2"})
    assert n == 1
    assert dep2.worker("onboard-infer") == "worker-2"


def test_deployer_rejects_unknown_node(cluster):
    dep = Deployer(cluster)
    with pytest.raises(KeyError):
        dep.apply(AppManifest("x", "nonexistent", factory=lambda: None))
