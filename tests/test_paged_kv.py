"""Paged KV cache: allocator bookkeeping, paged-vs-contiguous engine
equivalence (dense / moe / MLA), pool-exhaustion admission blocking,
memory accounting, and the dynamic MoE serving-prefill capacity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_reduced_config
from repro.models import moe as M
from repro.models import transformer as T
from repro.serving.batching import Request, poisson_trace
from repro.serving.engine import ContinuousEngine, PagedSlotManager
from repro.serving.paging import (BlockAllocator, PoolExhausted,
                                  default_pool_pages, pages_for)

from helpers import f32_cfg


@pytest.fixture(scope="module")
def cfg():
    return f32_cfg("smollm-360m")


@pytest.fixture(scope="module")
def params(cfg):
    return T.init_params(jax.random.PRNGKey(0), cfg, max_seq=64)


def _clone(reqs):
    return [r.clone() for r in reqs]


def _paired_tokens(res_a, res_b):
    """Results keyed by submission order (rids differ across engines)."""
    return [(res_a[a].tokens, res_b[b].tokens)
            for a, b in zip(sorted(res_a), sorted(res_b))]


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------

def test_block_allocator_reserve_alloc_release():
    a = BlockAllocator(8)
    assert a.available() == 8
    a.reserve(5)
    assert a.available() == 3 and a.can_reserve(3) and not a.can_reserve(4)
    ids = a.alloc(3)
    assert len(set(ids)) == 3 and all(1 <= i <= 8 for i in ids)
    assert a.in_use == 3 and a.reserved == 2
    a.release(ids, unreserve=2)            # evict before using the budget
    assert a.in_use == 0 and a.reserved == 0 and a.available() == 8
    assert a.peak_in_use == 3 and a.peak_committed == 5


def test_block_allocator_guards():
    a = BlockAllocator(2)
    with pytest.raises(PoolExhausted):
        a.reserve(3)
    with pytest.raises(PoolExhausted):
        a.alloc(1)                         # alloc without reservation
    a.reserve(2)
    ids = a.alloc(2)
    assert sorted(ids) == [1, 2]           # page 0 is never handed out
    a.release(ids)
    with pytest.raises(PoolExhausted):
        a.release([ids[0]])                # double release fails loudly
    with pytest.raises(PoolExhausted):
        a.release([0])                     # scratch page is not pooled


def test_pages_for_and_default_pool():
    assert pages_for(0, 16) == 0
    assert pages_for(1, 16) == 1
    assert pages_for(16, 16) == 1
    assert pages_for(17, 16) == 2
    # pool never smaller than one worst-case request
    assert default_pool_pages(1, 16, 16) == pages_for(16, 16)
    # and strictly below the contiguous layout at the benchmark shape
    assert default_pool_pages(4, 64, 16) * 16 < 4 * 64


# ---------------------------------------------------------------------------
# paged == contiguous (token-exactness across attention families)
# ---------------------------------------------------------------------------

def test_paged_matches_contiguous_trace(cfg, params):
    trace = poisson_trace(10, rate=0.7, prompt_lens=(3, 14), max_new=(1, 10),
                          vocab_size=cfg.vocab_size, seed=11)
    cont = ContinuousEngine(cfg, params, n_slots=3, max_seq=64,
                            kv_layout="contiguous").run(_clone(trace))
    paged = ContinuousEngine(cfg, params, n_slots=3, max_seq=64,
                             kv_layout="paged").run(_clone(trace))
    assert len(cont) == len(paged) == len(trace)
    for want, got in _paired_tokens(cont, paged):
        np.testing.assert_array_equal(got, want)


@pytest.mark.slow   # compiles prefill+decode per arch
@pytest.mark.parametrize("arch", [
    "qwen3-moe-30b-a3b",    # moe routing through paged pages
    "deepseek-v3-671b",     # MLA latent cache paged
])
def test_paged_matches_contiguous_all_families(arch):
    fam_cfg = f32_cfg(arch)
    fam_params = T.init_params(jax.random.PRNGKey(0), fam_cfg, max_seq=64)
    rng = np.random.default_rng(6)
    reqs = [Request(prompt=rng.integers(1, fam_cfg.vocab_size, 6)
                    .astype(np.int32), max_new=5),
            Request(prompt=rng.integers(1, fam_cfg.vocab_size, 9)
                    .astype(np.int32), max_new=7, arrival_t=2.0)]
    cont = ContinuousEngine(fam_cfg, fam_params, n_slots=2, max_seq=64,
                            kv_layout="contiguous").run(_clone(reqs))
    paged = ContinuousEngine(fam_cfg, fam_params, n_slots=2, max_seq=64,
                             kv_layout="paged").run(_clone(reqs))
    for want, got in _paired_tokens(cont, paged):
        np.testing.assert_array_equal(got, want)


def test_paged_is_default_for_dense(cfg, params):
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=64)
    assert eng.kv_layout == "paged"
    assert isinstance(eng.slots, PagedSlotManager)


def test_recurrent_families_keep_contiguous_state():
    zcfg = get_reduced_config("zamba2-7b")
    eng = ContinuousEngine(zcfg, {}, n_slots=1, max_seq=32)
    assert eng.kv_layout == "contiguous"
    with pytest.raises(NotImplementedError):
        ContinuousEngine(zcfg, {}, n_slots=1, max_seq=32, kv_layout="paged")


# ---------------------------------------------------------------------------
# pool exhaustion: admission blocks on pages, not slots
# ---------------------------------------------------------------------------

def test_pool_exhaustion_blocks_admission_then_drains(cfg, params):
    # pool of 4 pages; every request needs 2 -> only two of the three
    # requests fit concurrently even though 3 slots are free
    reqs = [Request(prompt=np.arange(1, 17, dtype=np.int32), max_new=9,
                    arrival_t=0.0) for _ in range(3)]
    eng = ContinuousEngine(cfg, params, n_slots=3, max_seq=64,
                           kv_layout="paged", page_size=16, pool_pages=4)
    for r in reqs:
        eng.submit(r)
    eng.step()
    assert len(eng.slots.active_slots()) == 2     # third blocked on pages
    assert len(eng.queue) == 1
    assert eng.slots.allocator.available() == 0
    results = eng.run()
    assert sorted(results) == sorted(r.rid for r in reqs)
    for r in reqs:
        assert len(results[r.rid].tokens) == r.max_new
    stats = eng.kv_cache_stats()
    assert stats["peak_pages_in_use"] <= stats["pool_pages"] == 4


def test_submit_rejects_request_larger_than_pool(cfg, params):
    eng = ContinuousEngine(cfg, params, n_slots=1, max_seq=64,
                           kv_layout="paged", page_size=16, pool_pages=2)
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.arange(1, 34, dtype=np.int32),
                           max_new=8))           # 40 positions > 2 pages


def test_paged_pool_uses_less_memory_than_contiguous(cfg, params):
    kw = dict(n_slots=4, max_seq=64)
    paged = ContinuousEngine(cfg, params, kv_layout="paged", **kw)
    cont = ContinuousEngine(cfg, params, kv_layout="contiguous", **kw)
    pb = paged.kv_cache_stats()["kv_cache_bytes"]
    cb = cont.kv_cache_stats()["kv_cache_bytes"]
    assert pb < cb, (pb, cb)


# (the hypothesis property test lives in test_property.py, which guards
# the optional dependency for the whole module)


def test_chunked_attention_kv_start_window():
    """kv_start lower-bounds valid positions per sequence — how the
    paged layout enforces a sliding window without a ring buffer."""
    from repro.models.attention import chunked_attention
    B, S, H, D = 3, 16, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D))
    lens = jnp.asarray([6, 11, 16], jnp.int32)
    starts = jnp.asarray([2, 0, 9], jnp.int32)
    got = chunked_attention(q, k, v, causal=False, kv_len=lens,
                            kv_start=starts)
    for i in range(B):
        lo, hi = int(starts[i]), int(lens[i])
        want = chunked_attention(q[i:i + 1], k[i:i + 1, lo:hi],
                                 v[i:i + 1, lo:hi], causal=False)
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want[0]),
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# dynamic MoE serving-prefill capacity
# ---------------------------------------------------------------------------

def test_moe_capacity_overflow_channel():
    cfg = f32_cfg("qwen3-moe-30b-a3b")
    params = T.init_params(jax.random.PRNGKey(0), cfg, max_seq=64)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))
    p = jax.tree.map(lambda a: a, params["blocks_moe"])
    layer0 = jax.tree.map(lambda a: a[0], p)["moe"]
    y_exact, _ = M.moe_fwd(layer0, cfg, x, drop_free=True)
    # tight capacity either reproduces the exact result (aux == 0) or
    # reports the overflow so the caller can retry
    y_cap, aux = M.moe_fwd(layer0, cfg, x, drop_free=True, capacity=4)
    if float(aux) == 0.0:
        np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_exact),
                                   atol=1e-6)
    else:
        assert float(aux) > 0
    # full capacity always matches exactly with a zero overflow count
    y_full, aux_full = M.moe_fwd(layer0, cfg, x, drop_free=True, capacity=16)
    assert float(aux_full) == 0.0
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_exact),
                               atol=1e-6)


def test_moe_dynamic_capacity_prefill_token_exact():
    cfg = f32_cfg("qwen3-moe-30b-a3b")
    params = T.init_params(jax.random.PRNGKey(0), cfg, max_seq=64)
    eng = ContinuousEngine(cfg, params, n_slots=1, max_seq=64)
    toks = np.random.default_rng(3).integers(
        1, cfg.vocab_size, (1, 16)).astype(np.int32)
    logits_dyn, _ = eng._run_prefill(toks)
    logits_exact, _, _ = T.forward(params, cfg, {"tokens": jnp.asarray(toks)},
                                   moe_drop_free=True, return_cache=True,
                                   remat=False)
    np.testing.assert_allclose(np.asarray(logits_dyn),
                               np.asarray(logits_exact), atol=1e-6)


def test_initial_capacity_bounds():
    cfg = get_reduced_config("qwen3-moe-30b-a3b")
    assert M.initial_capacity(cfg, 16) <= 16
    assert M.initial_capacity(cfg, 4096) >= 4
    assert M.initial_capacity(cfg, 4096) % 4 == 0
