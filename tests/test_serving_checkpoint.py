"""Serving engine, request batching, and checkpoint round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.config import get_reduced_config
from repro.models import transformer as T
from repro.serving.batching import Batch, Request, RequestQueue
from repro.serving.engine import ServingEngine

from helpers import f32_cfg


def test_generate_shapes_and_determinism():
    cfg = get_reduced_config("smollm-360m")
    eng = ServingEngine.init(cfg, max_seq=64)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(3, 12)).astype(np.int32)
    r1 = eng.generate(prompts, max_new=6)
    r2 = eng.generate(prompts, max_new=6)
    assert r1.tokens.shape == (3, 6)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)   # greedy = determ.


def test_generate_matches_forward_argmax():
    """The first generated token equals the argmax of full-forward logits
    at the last prompt position."""
    cfg = f32_cfg("qwen1.5-4b")
    eng = ServingEngine.init(cfg, max_seq=64)
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, size=(2, 10)).astype(np.int32)
    res = eng.generate(prompts, max_new=1)
    logits, _ = T.forward(eng.params, cfg, {"tokens": jnp.asarray(prompts)},
                          remat=False)
    want = np.asarray(jnp.argmax(logits[:, -1], -1))
    np.testing.assert_array_equal(res.tokens[:, 0], want)


def test_request_queue_batching():
    q = RequestQueue(max_batch=3, pad_id=0)
    rng = np.random.default_rng(0)
    for n in (5, 7, 3, 9):
        q.submit(Request(prompt=rng.integers(1, 100, n).astype(np.int32)))
    b1 = q.next_batch()
    assert isinstance(b1, Batch) and b1.tokens.shape == (3, 7)
    # left padding: the last token of each row is the prompt's last token
    for i, r in enumerate(b1.requests):
        assert b1.tokens[i, -1] == r.prompt[-1]
        assert b1.lengths[i] == len(r.prompt)
    b2 = q.next_batch()
    assert b2.tokens.shape == (1, 9)
    assert q.next_batch() is None


def test_checkpoint_roundtrip_bf16(tmp_path):
    cfg = get_reduced_config("qwen3-moe-30b-a3b")
    params = T.init_params(jax.random.PRNGKey(0), cfg, max_seq=32)
    path = str(tmp_path / "m.ckpt")
    size = save_checkpoint(path, params, {"arch": cfg.name})
    assert size > 0
    like = jax.eval_shape(lambda: params)
    restored, meta = load_checkpoint(path, like)
    assert meta["arch"] == cfg.name
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    params = {"w": jnp.ones((4, 4))}
    path = str(tmp_path / "m.ckpt")
    save_checkpoint(path, params)
    bad = {"w": jax.ShapeDtypeStruct((2, 2), jnp.float32)}
    with pytest.raises(ValueError):
        load_checkpoint(path, bad)
