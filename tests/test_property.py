"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.filtering import CloudFilterConfig, filter_tiles
from repro.core.gating import ConfidenceGate, accuracy_with_gate, calibrate_threshold
from repro.core.link import ContactSchedule, LinkModel
from repro.core.tiling import merge_tiles, split_frame
from repro.core.telemetry import Ledger
from repro.kernels import ref

SETTINGS = dict(max_examples=30, deadline=None)


# ---------------------------------------------------------------------------
# gating
# ---------------------------------------------------------------------------

@given(st.integers(1, 40), st.integers(2, 30),
       st.floats(0.0, 1.0), st.floats(0.0, 1.0))
@settings(**SETTINGS)
def test_gate_threshold_monotone(B, V, t_lo, t_hi):
    """A higher threshold never escalates fewer items."""
    t_lo, t_hi = min(t_lo, t_hi), max(t_lo, t_hi)
    logits = jax.random.normal(jax.random.PRNGKey(B * V), (B, V)) * 3
    lo = ConfidenceGate("max_prob", t_lo).decide(logits)["escalate"]
    hi = ConfidenceGate("max_prob", t_hi).decide(logits)["escalate"]
    assert int(hi.sum()) >= int(lo.sum())
    # escalation sets are nested
    assert bool(jnp.all(jnp.logical_or(~lo, hi)))


@given(st.integers(4, 200), st.floats(0.0, 1.0))
@settings(**SETTINGS)
def test_calibrate_threshold_respects_budget(n, budget):
    rng = np.random.default_rng(n)
    conf = rng.uniform(0, 1, n).astype(np.float32)
    thr = calibrate_threshold(conf, np.ones(n, bool), budget)
    esc_rate = float(np.mean(conf < thr))
    assert esc_rate <= budget + 1.0 / n + 1e-9


@given(st.integers(2, 100))
@settings(**SETTINGS)
def test_collaborative_accuracy_bounds(n):
    """System accuracy is between onboard-only and ground-only accuracy
    whenever the ground tier is no worse than the onboard tier on every
    escalated item subset."""
    rng = np.random.default_rng(n)
    onboard = rng.random(n) < 0.5
    ground = onboard | (rng.random(n) < 0.6)    # ground dominates
    esc = rng.random(n) < 0.4
    acc = accuracy_with_gate(onboard, ground, esc)
    assert acc >= np.mean(onboard) - 1e-9
    assert acc <= np.mean(ground) + 1e-9


# ---------------------------------------------------------------------------
# tiling
# ---------------------------------------------------------------------------

@given(st.integers(5, 64), st.integers(5, 64), st.sampled_from([4, 8, 16]))
@settings(**SETTINGS)
def test_tiling_roundtrip(H, W, tile):
    rng = np.random.default_rng(H * W)
    frame = rng.random((H, W, 3)).astype(np.float32)
    tiles = split_frame(jnp.asarray(frame), tile)
    back = merge_tiles(tiles, H, W)
    np.testing.assert_allclose(back, frame, atol=0)
    # tile count matches the grid
    assert tiles.shape[0] == (-(-H // tile)) * (-(-W // tile))


# ---------------------------------------------------------------------------
# link model
# ---------------------------------------------------------------------------

@given(st.floats(0.1, 40.0), st.integers(1, 10 ** 9))
@settings(**SETTINGS)
def test_link_time_positive_and_linear(mbps, nbytes):
    link = LinkModel(downlink_mbps=mbps)
    t1 = link.downlink_time_s(nbytes)
    t2 = link.downlink_time_s(2 * nbytes)
    assert t1 > 0 and np.isclose(t2, 2 * t1, rtol=1e-9)


@given(st.integers(1, 12), st.floats(60.0, 900.0))
@settings(**SETTINGS)
def test_contact_windows_ordered_disjoint(contacts, dur):
    sched = ContactSchedule(contact_duration_s=dur,
                            contacts_per_day=contacts, seed=contacts)
    wins = sched.windows(86_400.0)
    assert len(wins) >= contacts - 1
    for (a1, b1), (a2, b2) in zip(wins, wins[1:]):
        assert a1 < b1 <= a2 < b2 or b1 <= a2   # ordered, disjoint
    cap = sched.downlink_capacity_bytes(86_400.0)
    assert cap > 0


@given(st.integers(0, 2 ** 32 - 1),
       st.floats(0.0, 0.45), st.floats(0.0, 0.45),
       st.integers(16, 256),
       st.lists(st.integers(1, 600), min_size=1, max_size=8),
       st.integers(10, 200))
@settings(**SETTINGS)
def test_framed_lane_ledger_conserves_bytes(seed, loss, corrupt,
                                            frame_bytes, sizes, budget):
    """The framed lane's byte ledger conserves under ANY seeded fault
    plan: every attempted frame byte is accounted as delivered, lost,
    or corrupted-and-detected — and no payload ever completes with a
    failed CRC (zero silent corruptions, detections == injections)."""
    from repro.core.faults import FaultInjector, FaultPlan
    from repro.core.link import TransmitLane

    inj = FaultInjector(FaultPlan(seed=seed, frame_loss_rate=loss,
                                  frame_corrupt_rate=corrupt))
    lane = TransmitLane(frame_bytes=frame_bytes, max_retries=4,
                        injector=inj)
    for i, nb in enumerate(sizes):
        lane.enqueue(i, float(nb))
    done, failed = [], []
    for _ in range(500):
        done += lane.tick(float(budget))
        failed += [item for item, _ in lane.take_failed()]
        if len(lane) == 0:
            break
    assert abs(lane.frame_bytes_attempted
               - (lane.bytes_sent + lane.bytes_lost + lane.bytes_corrupt)
               ) < 1e-6
    assert lane.n_silent_corruptions == 0
    assert lane.n_corruptions_detected == inj.n_frame_corruptions
    assert lane.n_frames_lost == inj.n_frames_lost
    if len(lane) == 0:                       # drained within the bound
        assert sorted(done + failed) == list(range(len(sizes)))
        # goodput counts each completed payload's bytes exactly once
        assert lane.bytes_sent >= sum(
            sizes[i] for i in done) - 1e-6


# ---------------------------------------------------------------------------
# ledger
# ---------------------------------------------------------------------------

@given(st.integers(1, 1000), st.integers(0, 1000), st.integers(64, 4096))
@settings(**SETTINGS)
def test_ledger_data_reduction_bounds(n, n_esc_raw, item_bytes):
    n_esc = min(n_esc_raw, n)
    led = Ledger()
    led.add("items_total", n)
    led.add("items_escalated", n_esc)
    led.add("bytes_downlinked", 16 * (n - n_esc) + item_bytes * n_esc)
    led.add("bytes_bentpipe_baseline", item_bytes * n)
    s = led.summary()
    assert 0.0 <= s["escalation_rate"] <= 1.0
    if item_bytes > 16:
        assert s["data_reduction"] >= 0.0
    assert s["data_reduction"] <= 1.0


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------

@given(st.integers(1, 8), st.integers(8, 256), st.floats(0.01, 100.0))
@settings(**SETTINGS)
def test_int8_roundtrip_error_bound(N, D, scale):
    x = (jax.random.normal(jax.random.PRNGKey(N * D), (N, D)) * scale)
    q, s = ref.int8_quantize_ref(x)
    rec = ref.int8_dequantize_ref(q, s)
    # per-row error bounded by half a quantization step
    step = s[:, None]
    assert bool(jnp.all(jnp.abs(rec - x) <= 0.5 * step + 1e-6))


# ---------------------------------------------------------------------------
# confidence metrics
# ---------------------------------------------------------------------------

@given(st.integers(1, 8), st.integers(2, 64), st.floats(0.1, 10.0))
@settings(**SETTINGS)
def test_confidence_metric_ranges(B, V, scale):
    logits = jax.random.normal(jax.random.PRNGKey(B + V), (B, V)) * scale
    m = ref.confidence_gate_ref(logits)
    assert bool(jnp.all((m["max_prob"] > 0) & (m["max_prob"] <= 1 + 1e-6)))
    assert bool(jnp.all((m["entropy"] >= -1e-5)
                        & (m["entropy"] <= np.log(V) + 1e-4)))
    assert bool(jnp.all((m["margin"] >= -1e-6) & (m["margin"] <= 1 + 1e-6)))
    assert bool(jnp.all((m["argmax"] >= 0) & (m["argmax"] < V)))


# ---------------------------------------------------------------------------
# cloud filter
# ---------------------------------------------------------------------------

@given(st.integers(2, 12))
@settings(**SETTINGS)
def test_filter_drops_pure_cloud_keeps_texture(n):
    rng = np.random.default_rng(n)
    t = 16
    bright = np.clip(0.93 + 0.002 * rng.standard_normal((n, t, t, 3)), 0, 1)
    textured = np.clip(0.3 + 0.35 * rng.random((n, t, t, 3)), 0, 1)
    tiles = jnp.asarray(np.concatenate([bright, textured]).astype(np.float32))
    keep, stats = filter_tiles(tiles)
    keep = np.asarray(keep)
    assert not keep[:n].any()            # clouds dropped
    assert keep[n:].sum() >= 1           # at least some texture kept


# ---------------------------------------------------------------------------
# block allocator: reservation accounting is exact under random op traces
# ---------------------------------------------------------------------------

@given(st.integers(2, 24), st.lists(st.integers(0, 2 ** 31 - 1),
                                    min_size=1, max_size=40))
@settings(**SETTINGS)
def test_allocator_accounting_exact_under_random_ops(n_pages, op_seeds):
    """Drive a BlockAllocator with random (valid) reserve/alloc/release
    ops against a mirror model: in_use, reserved, and available() must
    stay exact, released tables must never double-free, and a full
    drain restores the pool bit-for-bit."""
    from repro.serving.paging import BlockAllocator, PoolExhausted
    a = BlockAllocator(n_pages)
    tables = []                       # (pages, outstanding_reservation)
    for seed in op_seeds:
        rng = np.random.default_rng(seed)
        op = rng.integers(0, 3)
        if op == 0 and a.available() > 0:          # admit: reserve + alloc
            budget = int(rng.integers(1, a.available() + 1))
            a.reserve(budget)
            first = int(rng.integers(1, budget + 1))
            pages = a.alloc(first)
            tables.append((pages, budget - first))
        elif op == 1 and tables:                   # grow one page
            i = int(rng.integers(len(tables)))
            pages, rest = tables[i]
            if rest > 0:
                pages.extend(a.alloc(1))
                tables[i] = (pages, rest - 1)
        elif op == 2 and tables:                   # evict
            pages, rest = tables.pop(int(rng.integers(len(tables))))
            a.release(pages, unreserve=rest)
        # the mirror model must agree exactly after every op
        assert a.in_use == sum(len(p) for p, _ in tables)
        assert a.reserved == sum(r for _, r in tables)
        assert a.available() == n_pages - a.in_use - a.reserved
        assert len(a._free) == n_pages - a.in_use
        assert a._free_set == set(a._free)
    drained = []
    for pages, rest in tables:
        a.release(pages, unreserve=rest)
        drained.extend(pages)
    if drained:
        with pytest.raises(PoolExhausted):         # no double free, ever
            a.release([drained[0]])
    assert a.in_use == 0 and a.reserved == 0 and a.available() == n_pages


# ---------------------------------------------------------------------------
# block allocator: refcounted sharing against an exact mirror model
# ---------------------------------------------------------------------------

@given(st.integers(4, 24), st.lists(st.integers(0, 2 ** 31 - 1),
                                    min_size=1, max_size=50))
@settings(**SETTINGS)
def test_allocator_refcount_sharing_exact_under_random_ops(n_pages, op_seeds):
    """Random share/fork/spill/resume/finish interleavings against a
    mirror refcount model: a page is live iff some table references it,
    in_use counts DISTINCT live pages, n_live_refs counts references,
    no interleaving leaks a page or frees one twice, and every page
    hits refcount zero exactly once (the release after that raises)."""
    from repro.serving.paging import BlockAllocator, PoolExhausted
    a = BlockAllocator(n_pages)
    rc = {}                            # mirror: page id -> reference count
    tables = []                        # (pages, outstanding_reservation)

    def deref(pages):
        for i in pages:
            rc[i] -= 1
            if not rc[i]:
                del rc[i]

    for seed in op_seeds:
        rng = np.random.default_rng(seed)
        op = rng.integers(0, 5)
        if op == 0 and a.available() > 0:          # admit: reserve + alloc
            budget = int(rng.integers(1, a.available() + 1))
            a.reserve(budget)
            first = int(rng.integers(1, budget + 1))
            pages = a.alloc(first)
            rc.update((i, 1) for i in pages)       # fresh pages: one ref
            tables.append((pages, budget - first))
        elif op == 1 and tables:                   # fork: share a prefix
            src = tables[int(rng.integers(len(tables)))][0]
            if src:
                shared = list(src[:int(rng.integers(1, len(src) + 1))])
                a.share(shared)
                for i in shared:
                    rc[i] += 1
                tables.append((shared, 0))
        elif op == 2 and tables:                   # grow/resume one page
            i = int(rng.integers(len(tables)))
            pages, rest = tables[i]
            if rest > 0:
                new = a.alloc(1)
                rc[new[0]] = 1
                pages.extend(new)
                tables[i] = (pages, rest - 1)
        elif op == 3 and tables:                   # spill: drop a suffix
            i = int(rng.integers(len(tables)))
            pages, rest = tables[i]
            if pages:
                cut = int(rng.integers(len(pages)))
                a.release(pages[cut:])
                deref(pages[cut:])
                tables[i] = (pages[:cut], rest)
        elif op == 4 and tables:                   # finish: release all
            pages, rest = tables.pop(int(rng.integers(len(tables))))
            a.release(pages, unreserve=rest)
            deref(pages)
        # the mirror must agree exactly after every op
        assert a.in_use == len(rc)
        assert a.n_live_refs() == sum(rc.values())
        assert all(a.refcount(i) == n for i, n in rc.items())
        assert a.reserved == sum(r for _, r in tables)
        assert len(a._free) == n_pages - a.in_use
        assert a._free_set == set(a._free)
        assert a.available() == n_pages - a.in_use - a.reserved
    for pages, rest in tables:                     # drain everything
        a.release(pages, unreserve=rest)
        deref(pages)
    assert not rc and a.in_use == 0 and a.reserved == 0
    assert a.n_live_refs() == 0 and a.available() == n_pages
    with pytest.raises(PoolExhausted):             # refcount 0 is final:
        a.release([1])                             # no second free...
    with pytest.raises(PoolExhausted):
        a.share([1])                               # ...and no revival


# ---------------------------------------------------------------------------
# preemptive scheduler: invariants under random arrival/preempt/resume traces
# ---------------------------------------------------------------------------

@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=5, deadline=None)
def test_scheduler_invariants_random_preemption(seed):
    """Random Poisson arrivals with random priorities, random preemption
    (random slot, random spill/resident mode) and random transmit-lane
    page holds injected at random ticks: every admitted request finishes
    with exactly max_new tokens (no starvation), the allocator's free
    count is fully restored after the drain (no page leak), reservation
    accounting ends exact, and the KV-delta spill ledger stays
    consistent (delta bytes never exceed the full-spill equivalent; the
    host store drains with the work).  Double-free would raise
    PoolExhausted mid-run."""
    from repro.serving.batching import poisson_trace
    from repro.serving.engine import ContinuousEngine
    from repro.serving.scheduler import PreemptiveScheduler
    cfg, params = _paged_cfg_params()
    rng = np.random.default_rng(seed)
    trace = poisson_trace(5, rate=0.9, prompt_lens=(2, 12), max_new=(1, 7),
                          vocab_size=cfg.vocab_size, seed=seed,
                          priorities=(0, 2))
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=32,
                           kv_layout="paged", page_size=8)
    sched = PreemptiveScheduler(eng)
    for r in sorted(trace, key=lambda r: r.arrival_t):
        sched.submit(r)
    guard = 0
    hold_until = -1
    while sched.has_work():
        guard += 1
        assert guard < 500, "scheduler failed to drain (starvation?)"
        active = eng.slots.active_slots()
        if active and rng.random() < 0.3:
            slot = int(rng.choice(active))
            sched.preempt(slot,
                          "spill" if rng.random() < 0.7 else "resident")
        if hold_until < 0 and rng.random() < 0.15:
            # a pass opens: hold a random comm reserve for a few ticks
            sched.hold_pages(int(rng.integers(1, 6)))
            hold_until = guard + int(rng.integers(1, 6))
        if 0 <= hold_until <= guard:
            sched.release_hold()                    # the pass closes
            hold_until = -1
        sched.step(decode=bool(rng.random() < 0.9))
    sched.release_hold()
    results = sched.results
    assert sorted(results) == sorted(r.rid for r in trace)   # no starvation
    by_rid = {r.rid: r for r in trace}
    for rid, res in results.items():
        assert len(res.tokens) == by_rid[rid].max_new
        # the final token always came from the recorded final logits
        assert int(np.argmax(res.logits_last)) == int(res.tokens[-1])
    alloc = eng.slots.allocator
    assert alloc.in_use == 0 and alloc.reserved == 0        # no page leak
    assert len(alloc._free) == alloc.n_pages                # count restored
    assert alloc._free_set == set(alloc._free)              # no double free
    assert sched.n_resumes == sched.n_preemptions
    # delta-spill ledger invariants
    s = sched.stats()
    assert s["n_delta_spills"] <= s["n_spills"] == sched.n_spills
    assert 0 <= s["spill_bytes"] <= s["spill_bytes_full_equiv"]
    assert len(sched.store) == 0     # every record dropped at finish
    assert sched.held_pages == 0


# ---------------------------------------------------------------------------
# KV-delta spill store: merged snapshots match a full-copy reference
# ---------------------------------------------------------------------------

@given(st.sampled_from([2, 4, 8]),
       st.lists(st.integers(1, 4), min_size=1, max_size=6),
       st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_delta_store_merge_matches_full_reference(ps, growths, seed):
    """Random grow/dirty/spill cycles against a mirror array: after
    every merge the store's reassembled snapshot equals the full
    reference bit-for-bit, its watermark tracks the live page count,
    and the byte ledger never claims a delta larger than the full
    spill."""
    from repro.serving.paging import DeltaSpillStore
    rng = np.random.default_rng(seed)
    store = DeltaSpillStore(ps)
    ref = {"k": np.zeros((2, 1, 0, 3), np.float32),
           "v": np.zeros((2, 1, 0, 3), np.float32)}
    total, synced, rid = 0, 0, 7
    for g in growths:
        # grow g fresh pages, and dirty every page from a random point
        # at or below the current watermark (decode writes move the
        # watermark down; growth appends above it)
        w = int(rng.integers(0, synced + 1))
        grown = {k: np.concatenate(
            [a, np.zeros((2, 1, g * ps, 3), np.float32)], axis=2)
            for k, a in ref.items()}
        total += g
        for k, a in grown.items():
            a[:, :, w * ps:] = rng.standard_normal(
                (2, 1, (total - w) * ps, 3))
        ref = grown
        delta = {k: a[:, :, w * ps:] for k, a in ref.items()}
        merged = store.merge(rid, delta, w, total)
        for k in ref:
            np.testing.assert_array_equal(merged[k], ref[k])
        assert store.synced_pages(rid) == total
        synced = total
    assert store.bytes_spilled <= store.bytes_full_equiv
    assert store.n_spills == len(growths)
    # a re-spill with nothing dirtied ships zero new bytes
    before = store.bytes_spilled
    merged = store.merge(rid, None, total, total)
    for k in ref:
        np.testing.assert_array_equal(merged[k], ref[k])
    assert store.bytes_spilled == before
    store.drop(rid)
    assert rid not in store and len(store) == 0


# ---------------------------------------------------------------------------
# paged KV serving: paged decode is token-exact with the contiguous engine
# ---------------------------------------------------------------------------

_PAGED_CACHE = {}


def _paged_cfg_params():
    if not _PAGED_CACHE:
        from helpers import f32_cfg
        from repro.models import transformer as T
        cfg = f32_cfg("smollm-360m")
        _PAGED_CACHE["cfg"] = cfg
        _PAGED_CACHE["params"] = T.init_params(
            jax.random.PRNGKey(0), cfg, max_seq=64)
    return _PAGED_CACHE["cfg"], _PAGED_CACHE["params"]


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([3, 4, 8, 16]))
@settings(max_examples=5, deadline=None)
def test_unified_step_token_budget_invariant(seed, budget):
    """Random traces at random prefill budgets: no tick's mixed batch
    ever exceeds ``budget`` prefill-chunk tokens plus ``n_slots`` decode
    tokens, every admitted request still finishes with exactly its
    max_new tokens, and the page pool drains."""
    from repro.serving.batching import poisson_trace
    from repro.serving.engine import ContinuousEngine
    cfg, params = _paged_cfg_params()
    trace = poisson_trace(5, rate=0.9, prompt_lens=(2, 20), max_new=(1, 7),
                          vocab_size=cfg.vocab_size, seed=seed)
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=32,
                           kv_layout="paged", page_size=8,
                           prefill_budget_tokens=budget)
    for r in sorted(trace, key=lambda r: r.arrival_t):
        eng.submit(r)
    while len(eng.queue) or eng.slots.any_active():
        eng.step()
        assert eng.last_tick_prefill_tokens <= budget
        assert eng.last_tick_decode_tokens <= 2
        assert (eng.last_tick_prefill_tokens
                + eng.last_tick_decode_tokens) <= budget + 2
    by_rid = {r.rid: r for r in trace}
    assert sorted(eng.results) == sorted(by_rid)     # no starvation
    for rid, res in eng.results.items():
        assert len(res.tokens) == by_rid[rid].max_new
        assert res.admitted_step <= res.first_token_step <= res.finished_step
    alloc = eng.slots.allocator
    assert alloc.in_use == 0 and alloc.reserved == 0


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 3),
       st.sampled_from([8, 16]))
@settings(max_examples=5, deadline=None)
def test_paged_engine_matches_contiguous(seed, n_slots, page_size):
    from repro.serving.batching import poisson_trace
    from repro.serving.engine import ContinuousEngine
    cfg, params = _paged_cfg_params()
    trace = poisson_trace(5, rate=0.9, prompt_lens=(2, 12), max_new=(1, 7),
                          vocab_size=cfg.vocab_size, seed=seed)

    def run(layout, **kw):
        eng = ContinuousEngine(cfg, params, n_slots=n_slots, max_seq=32,
                               kv_layout=layout, **kw)
        return eng.run([r.clone() for r in trace])

    cont = run("contiguous")
    paged = run("paged", page_size=page_size)
    for a, b in zip(sorted(cont), sorted(paged)):
        np.testing.assert_array_equal(paged[b].tokens, cont[a].tokens)


# ---------------------------------------------------------------------------
# constellation: station capacity + single ownership
# ---------------------------------------------------------------------------

@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 5), st.integers(1, 3))
@settings(**SETTINGS)
def test_planner_station_capacity_conserved(seed, n_sats, n_stations):
    """Random window sets and random demands: every tick's grant set
    uses at most ``n_stations`` station-lanes (assigned pass seconds per
    tick <= stations * s_per_step), never grants two stations to one
    satellite, and never grants outside a visibility window."""
    from repro.serving.constellation import ContactPlanner
    rng = np.random.default_rng(seed)
    ws = {}
    for k in range(n_sats):
        for m in range(n_stations):
            wins, t = [], 0
            for _ in range(int(rng.integers(0, 4))):
                lo = t + int(rng.integers(0, 20))
                hi = lo + int(rng.integers(1, 15))
                wins.append((lo, hi))
                t = hi + int(rng.integers(0, 10))
            ws[(k, m)] = wins
    planner = ContactPlanner(ws, n_sats, n_stations,
                             policy=["value", "static"][seed % 2])
    for t in range(0, 80, 7):
        demands = {k: (float(rng.integers(0, 50)),
                       float(rng.integers(1, 8))) for k in range(n_sats)}
        grants = planner.assign(t, demands)
        assert len(grants) <= n_stations
        assert len(set(grants.values())) == len(grants)   # one station/sat
        for m, k in grants.items():
            assert planner.in_window(k, m, t)
            assert demands[k][0] > 0


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=3, deadline=None)
def test_constellation_single_ownership_random_trace(seed):
    """A random trace driven tick by tick through a 2-satellite
    constellation with handovers: no rid is ever owned by two
    satellites, planner grants respect capacity every tick, the fleet
    drains (pools + spill stores empty) and answers are token-exact
    against an uninterrupted engine."""
    from repro.serving.batching import Request, poisson_trace
    from repro.serving.constellation import ConstellationScheduler
    from repro.serving.engine import ContinuousEngine
    cfg, params = _paged_cfg_params()
    trace = poisson_trace(4, rate=0.9, prompt_lens=(2, 10), max_new=(1, 6),
                          vocab_size=cfg.vocab_size, seed=seed,
                          priorities=(0, 2))
    want = {}
    for r in trace:
        solo = ContinuousEngine(cfg, params, n_slots=2, max_seq=32,
                                kv_layout="paged", page_size=8)
        res = solo.run([Request(prompt=r.prompt.copy(), max_new=r.max_new)])
        want[r.rid] = np.asarray(next(iter(res.values())).tokens)
    engines = [ContinuousEngine(cfg, params, n_slots=2, max_seq=32,
                                kv_layout="paged", page_size=8)
               for _ in range(2)]
    # satellite 0 sees its only station late; satellite 1 sees it early
    ws = {(0, 0): [(400, 500)], (1, 0): [(3, 500)]}
    cs = ConstellationScheduler(engines, window_sets=ws, n_stations=1,
                                s_per_step=1.0, horizon_s=600.0,
                                handover_margin_ticks=8)
    for r in sorted(trace, key=lambda r: r.arrival_t):
        cs.sats[0].submit(r)
    guard = 0
    while cs.has_work() and cs.clock < cs.horizon_steps:
        cs.tick()
        guard += 1
        assert guard < 2000
        assert all(len(s) == 1 for s in cs.ownership().values())
        assert len(cs.last_assignment) <= 1
    rep = cs.report()
    assert not rep.undelivered
    assert rep.n_handovers > 0
    for rid, toks in rep.tokens.items():
        np.testing.assert_array_equal(toks, want[rid])
    for sat in cs.sats:
        alloc = sat.engine.slots.allocator
        assert alloc.in_use == 0 and alloc.reserved == 0
        assert len(sat.store) == 0
