"""HLO analyzer: trip-count-aware flops/bytes/collectives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import Analyzer, analyze_hlo, parse_module


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_scan_flops_scale_with_trip_count():
    def make(n):
        def step(x, _):
            return x @ x, None
        return lambda x: jax.lax.scan(step, x, None, length=n)[0]
    s = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    f10 = analyze_hlo(_compile(make(10), s))["flops"]
    f40 = analyze_hlo(_compile(make(40), s))["flops"]
    want = 2 * 256 ** 3
    assert abs(f10 - 10 * want) / (10 * want) < 0.01
    assert abs(f40 - 40 * want) / (40 * want) < 0.01


def test_dot_flops_exact_unrolled():
    def fn(a, b):
        return a @ b
    sa = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    sb = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    got = analyze_hlo(_compile(fn, sa, sb))["flops"]
    assert got == 2 * 64 * 128 * 32


def test_parse_module_finds_entry_and_computations():
    def fn(x):
        def step(c, _):
            return jnp.tanh(c @ c), None
        return jax.lax.scan(step, x, None, length=4)[0]
    text = _compile(fn, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    comps, entry = parse_module(text)
    assert entry is not None and entry in comps
    assert any("while" in op.kind for op in comps[entry].ops) or \
        any("while" in o.kind for c in comps.values() for o in c.ops)


def test_collectives_counted_inside_loops():
    """Handcrafted partitioned-HLO snippet: an all-gather inside a while
    body with trip count 7 must be counted 7 times."""
    text = """
HloModule test

%body (p: (s32[], f32[16])) -> (s32[], f32[16]) {
  %p = (s32[], f32[16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[16] get-tuple-element(%p), index=1
  %ag = f32[64] all-gather(%x), dimensions={0}
  %y = f32[16] slice(%ag), slice={[0:16]}
  %c1 = s32[] constant(1)
  %i2 = s32[] add(%i, %c1)
  ROOT %t = (s32[], f32[16]) tuple(%i2, %y)
}

%cond (p: (s32[], f32[16])) -> pred[] {
  %p = (s32[], f32[16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[16]) -> f32[16] {
  %x = f32[16] parameter(0)
  %c0 = s32[] constant(0)
  %t = (s32[], f32[16]) tuple(%c0, %x)
  %w = (s32[], f32[16]) while(%t), condition=%cond, body=%body
  ROOT %out = f32[16] get-tuple-element(%w), index=1
}
"""
    res = analyze_hlo(text)
    assert res["coll"]["all-gather"]["count"] == 7
    assert res["coll"]["all-gather"]["bytes"] == 7 * 64 * 4
    assert res["total_link_bytes"] == 7 * 64 * 4


def test_elementwise_flops_counted():
    def fn(x):
        return jnp.tanh(x) + x * 2.0
    got = analyze_hlo(_compile(
        fn, jax.ShapeDtypeStruct((128, 128), jnp.float32)))["flops"]
    assert got >= 2 * 128 * 128     # at least tanh + mul + add fused count
