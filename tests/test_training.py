"""Training substrate: optimizer math, loss descent, federated
aggregation, incremental adaptation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_reduced_config
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.training import optim
from repro.training.federated import FedConfig, fedavg, run_federated
from repro.training.incremental import IncrementalConfig, incremental_update
from repro.training.loop import init_state, train


def test_adamw_matches_reference_on_quadratic():
    """Minimize 0.5*||x||^2; compare against a hand-rolled AdamW."""
    cfg = optim.OptimConfig(lr=0.1, warmup_steps=0, total_steps=10 ** 9,
                            weight_decay=0.0, grad_clip=1e9)
    x = {"w": jnp.array([1.0, -2.0, 3.0])}
    state = optim.adamw_init(x, cfg)
    xs = np.array([1.0, -2.0, 3.0])
    m = np.zeros(3)
    v = np.zeros(3)
    for t in range(1, 6):
        g = np.array(x["w"])                     # grad of 0.5||x||^2 = x
        x, state, _ = optim.adamw_update(x, {"w": jnp.asarray(g)}, state, cfg)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g ** 2
        mh, vh = m / (1 - cfg.b1 ** t), v / (1 - cfg.b2 ** t)
        lr = optim.lr_schedule(cfg, jnp.int32(t))
        xs = xs - float(lr) * mh / (np.sqrt(vh) + cfg.eps)
        np.testing.assert_allclose(np.array(x["w"]), xs, rtol=1e-5)


def test_lr_schedule_shape():
    cfg = optim.OptimConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(optim.lr_schedule(cfg, jnp.int32(s))) for s in
           (1, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]              # warmup rises
    assert lrs[2] >= lrs[3] >= lrs[4]            # cosine decays
    assert abs(lrs[2] - 1e-3) < 1e-9


@pytest.mark.slow
def test_loss_decreases_on_learnable_stream():
    cfg = get_reduced_config("smollm-360m")
    opt_cfg = optim.OptimConfig(lr=2e-3, warmup_steps=5, total_steps=60)
    stream = TokenStream(TokenStreamConfig(vocab_size=cfg.vocab_size,
                                           seq_len=128, batch_size=8))
    state = init_state(cfg, opt_cfg, max_seq=128)
    state = train(cfg, state, iter(stream), opt_cfg, steps=60, log_every=10)
    first = state.history[0]["loss"]
    last = state.history[-1]["loss"]
    assert last < first - 0.3, (first, last)


def test_fedavg_weighted_mean():
    g = {"w": jnp.zeros(3)}
    p1 = {"w": jnp.ones(3)}
    p2 = {"w": 3 * jnp.ones(3)}
    out = fedavg(g, [p1, p2], [1.0, 1.0])
    np.testing.assert_allclose(np.array(out["w"]), 2.0 * np.ones(3))
    # zero weights -> unchanged global
    out2 = fedavg(g, [p1, p2], [0.0, 0.0])
    np.testing.assert_allclose(np.array(out2["w"]), 0.0)


@pytest.mark.slow
def test_federated_round_improves_loss():
    cfg = get_reduced_config("smollm-360m")
    fed = FedConfig(n_satellites=2, local_steps=8, rounds=2)

    def make_data(i):
        return iter(TokenStream(TokenStreamConfig(
            vocab_size=cfg.vocab_size, seq_len=64, batch_size=4,
            seed=100 + i)))

    out = run_federated(cfg, fed, make_data, max_seq=64)
    assert len(out["rounds"]) == 2
    losses = [r["local_losses"][0] for r in out["rounds"]]
    assert losses[-1] < losses[0] + 0.1    # no divergence across rounds
    assert all(0 < w <= 1 for r in out["rounds"] for w in r["weights"])


@pytest.mark.slow
def test_incremental_update_adapts_to_drift():
    cfg = get_reduced_config("smollm-360m")
    opt_cfg = optim.OptimConfig(lr=2e-3, warmup_steps=2, total_steps=40)
    old = TokenStream(TokenStreamConfig(vocab_size=cfg.vocab_size,
                                        seq_len=64, batch_size=4, seed=0))
    new = TokenStream(TokenStreamConfig(vocab_size=cfg.vocab_size,
                                        seq_len=64, batch_size=4, seed=999))
    state = init_state(cfg, opt_cfg, max_seq=64)
    state = train(cfg, state, iter(old), opt_cfg, steps=30, log_every=10)
    state = incremental_update(cfg, state, iter(new),
                               inc=IncrementalConfig(finetune_steps=15))
    assert state.step == 45
    assert np.isfinite(state.history[-1]["loss"])
