"""Speculative collaborative decoding: provable equality with the ground
tier's greedy output + acceptance accounting."""
import numpy as np
import pytest

from repro.configs import tiansuan_pair as TP
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.serving.speculative import (greedy_generate, speculative_generate)
from repro.training import optim
from repro.training.loop import init_state, train

pytestmark = pytest.mark.slow   # trains the draft/target pair


@pytest.fixture(scope="module")
def pair():
    stream = TokenStream(TokenStreamConfig(vocab_size=TP.ONBOARD.vocab_size,
                                           seq_len=96, batch_size=8))
    out = {}
    for name, cfg, steps in (("draft", TP.ONBOARD, 25),
                             ("target", TP.GROUND, 60)):
        opt = optim.OptimConfig(lr=2e-3, warmup_steps=5, total_steps=steps)
        st = init_state(cfg, opt, max_seq=160)
        st = train(cfg, st, iter(stream), opt, steps=steps, log_every=steps)
        out[name] = (cfg, st.params)
    out["stream"] = stream
    return out


def test_speculative_matches_target_greedy(pair):
    dcfg, dparams = pair["draft"]
    tcfg, tparams = pair["target"]
    prompt = pair["stream"].batch(5_000)["tokens"][0, :24]
    want = greedy_generate(tparams, tcfg, prompt, max_new=12)
    got = speculative_generate(dparams, dcfg, tparams, tcfg, prompt,
                               max_new=12, k=4)
    np.testing.assert_array_equal(got.tokens, want)
    assert got.rounds <= 12                     # never worse than greedy
    assert 0.0 <= got.acceptance_rate <= 1.0
    assert got.ledger.get("tokens_produced") == 12


def test_speculative_saves_rounds_when_tiers_agree(pair):
    """Trained on the same stream, the tiers agree often enough that
    verify rounds < tokens produced (the communication win)."""
    dcfg, dparams = pair["draft"]
    tcfg, tparams = pair["target"]
    total_rounds = 0
    total_tokens = 0
    for i in (1_000, 2_000, 3_000):
        prompt = pair["stream"].batch(i)["tokens"][0, :32]
        r = speculative_generate(dparams, dcfg, tparams, tcfg, prompt,
                                 max_new=10, k=4)
        total_rounds += r.rounds
        total_tokens += len(r.tokens)
    assert total_rounds < total_tokens
