"""Speculative draft-verify decoding: provable equality with the ground
tier's greedy output, one-pass engine verification, and acceptance /
uplink accounting.

The fast tests run on UNTRAINED fp32 params — greedy exactness is a
property of the verify algebra (argmax agreement over one chunk pass),
not of trained weights.  Only the agreement-RATE tests at the bottom
need the trained pair and stay slow-marked.
"""
import jax
import numpy as np
import pytest

from repro.configs import tiansuan_pair as TP
from repro.models import transformer as T
from repro.serving.batching import Request
from repro.serving.engine import ContinuousEngine
from repro.serving.speculative import (SpeculativeDecoder, greedy_generate,
                                       speculative_generate)

MAX_SEQ = 64


@pytest.fixture(scope="module")
def pair_cfgs():
    onboard = TP.ONBOARD.with_(param_dtype="float32",
                               activation_dtype="float32")
    ground = TP.GROUND.with_(param_dtype="float32",
                             activation_dtype="float32")
    return onboard, ground


@pytest.fixture(scope="module")
def pair_params(pair_cfgs):
    onboard, ground = pair_cfgs
    return (T.init_params(jax.random.PRNGKey(0), onboard, max_seq=MAX_SEQ),
            T.init_params(jax.random.PRNGKey(1), ground, max_seq=MAX_SEQ))


def _prompt(cfg, S, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, cfg.vocab_size, S).astype(np.int32)


def _assert_drained(eng):
    alloc = eng.slots.allocator
    assert alloc.in_use == 0
    assert alloc.reserved == 0


# -- greedy exactness (the tentpole contract) ------------------------------

def test_speculative_matches_greedy_cross_model(pair_cfgs, pair_params):
    """Untrained tiers disagree on almost every draft; the output must
    STILL equal plain greedy decoding of the target tier alone."""
    (dcfg, tcfg), (dparams, tparams) = pair_cfgs, pair_params
    prompt = _prompt(tcfg, 16, seed=3)
    want = greedy_generate(tparams, tcfg, prompt, max_new=12)
    got = speculative_generate(dparams, dcfg, tparams, tcfg, prompt,
                               max_new=12, k=4)
    np.testing.assert_array_equal(got.tokens, want)
    assert got.tokens.dtype == np.int32
    assert want.dtype == np.int32
    assert got.rounds <= 12                  # never worse than greedy
    assert 0.0 <= got.acceptance_rate <= 1.0
    assert got.ledger.get("tokens_produced") == 12


def test_self_draft_truncation_accounting(pair_cfgs, pair_params):
    """Regression for the metering bug this PR fixes: with the SAME
    model drafting and verifying, every draft is accepted — and with
    ``max_new % (k+1) != 0`` the final round must DRAFT fewer tokens
    rather than draft ahead and truncate, so accepted == drafted and
    the uplink ledger only ever meters shipped ids.

    max_new=9, k=4: the engine emits 2 tokens at prefill (prefill token
    + same-tick decode), then rounds of k_eff = min(4, rem-1) drafts:
    4 drafted (5 emitted) then 1 drafted (2 emitted) — 5 drafted total,
    uplink (4*4+16) + (4*1+16) = 52 bytes, never 8 drafts for 7 slots.
    """
    (dcfg, _), (dparams, _) = pair_cfgs, pair_params
    prompt = _prompt(dcfg, 12, seed=7)
    want = greedy_generate(dparams, dcfg, prompt, max_new=9)
    got = speculative_generate(dparams, dcfg, dparams, dcfg, prompt,
                               max_new=9, k=4)
    np.testing.assert_array_equal(got.tokens, want)
    assert got.rounds == 2
    assert got.drafted == got.accepted == 5
    assert got.acceptance_rate == 1.0
    assert got.ledger.get("uplink_bytes") == 52
    assert got.ledger.get("tokens_produced") == 9


# -- the engine's one-pass k-token verify ----------------------------------

def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_seq", MAX_SEQ)
    kw.setdefault("draft_k", 8)
    return ContinuousEngine(cfg, params, **kw)


def _plain_tokens(cfg, params, reqs):
    eng = _engine(cfg, params)
    res = eng.run([r.clone() for r in reqs])
    return [np.asarray(res[k].tokens, np.int32)
            for k in sorted(res)], eng.clock


def test_engine_verifies_k_drafts_in_one_pass(pair_cfgs, pair_params):
    """Requests carrying their own plain-run output as a draft stream
    replay token-exactly with every draft accepted through chunked
    verify passes — in strictly fewer engine ticks than plain decode."""
    (cfg, _), (params, _) = pair_cfgs, pair_params
    reqs = [Request(prompt=_prompt(cfg, S, seed=S), max_new=16)
            for S in (8, 11, 14)]
    plain, plain_clock = _plain_tokens(cfg, params, reqs)

    eng = _engine(cfg, params)
    spec_reqs = [r.clone() for r in reqs]
    for r, toks in zip(spec_reqs, plain):
        r.draft_toks = toks
    res = eng.run(spec_reqs)
    got = [np.asarray(res[k].tokens, np.int32) for k in sorted(res)]
    for a, b in zip(got, plain):
        np.testing.assert_array_equal(a, b)
    st = eng.spec_stats()
    # max_new=16: 2 tokens at prefill, then 14 verified through passes
    # of up to draft_k+1 emitted tokens each — at least ceil(14/9) = 2
    # passes per request, and every self-draft accepted
    assert st["verify_passes"] >= 2 * len(reqs)
    assert st["drafted"] == st["accepted"] > 0
    assert st["draft_streams_dropped"] == 0
    assert eng.clock < plain_clock
    _assert_drained(eng)


def test_engine_verify_survives_corrupted_draft_tail(pair_cfgs,
                                                     pair_params):
    """A wrong token mid-stream costs acceptance (everything after the
    first disagreement is rejected) but never correctness."""
    (cfg, _), (params, _) = pair_cfgs, pair_params
    reqs = [Request(prompt=_prompt(cfg, 10, seed=21), max_new=12)]
    (plain,), _ = _plain_tokens(cfg, params, reqs)

    bad = plain.copy()
    bad[5] = (bad[5] + 1) % cfg.vocab_size
    eng = _engine(cfg, params)
    res = eng.run([Request(prompt=reqs[0].prompt.copy(), max_new=12,
                           draft_toks=bad)])
    (result,) = res.values()
    np.testing.assert_array_equal(result.tokens, plain)
    st = eng.spec_stats()
    assert 0 < st["accepted"] < st["drafted"]
    _assert_drained(eng)


def test_engine_drops_mismatched_draft_head(pair_cfgs, pair_params):
    """``draft_toks[0]`` must equal the prefill's own first token — a
    mismatched head means the stream was drafted off a different prefix
    and the whole stream is dropped (counted, never verified)."""
    (cfg, _), (params, _) = pair_cfgs, pair_params
    reqs = [Request(prompt=_prompt(cfg, 10, seed=33), max_new=8)]
    (plain,), _ = _plain_tokens(cfg, params, reqs)

    bad = plain.copy()
    bad[0] = (bad[0] + 1) % cfg.vocab_size
    eng = _engine(cfg, params)
    res = eng.run([Request(prompt=reqs[0].prompt.copy(), max_new=8,
                           draft_toks=bad)])
    (result,) = res.values()
    np.testing.assert_array_equal(result.tokens, plain)
    st = eng.spec_stats()
    assert st["draft_streams_dropped"] == 1
    assert st["verify_passes"] == 0
    _assert_drained(eng)


# -- validation (must hold under ``python -O``: real raises, not asserts) --

def test_rejects_batched_prompt(pair_cfgs, pair_params):
    (dcfg, tcfg), (dparams, tparams) = pair_cfgs, pair_params
    batched = _prompt(tcfg, 8)[None, :]
    with pytest.raises(ValueError, match="single"):
        greedy_generate(tparams, tcfg, batched, max_new=4)
    with pytest.raises(ValueError, match="single"):
        speculative_generate(dparams, dcfg, tparams, tcfg, batched,
                             max_new=4)


def test_rejects_bad_k_and_draft_budgets(pair_cfgs, pair_params):
    (dcfg, tcfg), (dparams, tparams) = pair_cfgs, pair_params
    prompt = _prompt(tcfg, 8)
    with pytest.raises(ValueError, match="k must be"):
        speculative_generate(dparams, dcfg, tparams, tcfg, prompt, k=0)
    with pytest.raises(ValueError, match="draft_k"):
        _engine(tcfg, tparams, draft_k=0)
    # a decoder whose k exceeds the target engine's per-pass budget
    # would need multiple verify passes per round — rejected up front
    drf = _engine(dcfg, dparams, n_slots=1)
    tgt = _engine(tcfg, tparams, n_slots=1, draft_k=2)
    with pytest.raises(ValueError, match="exceeds"):
        SpeculativeDecoder(drf, tgt, k=4)


def test_rejects_batched_draft_stream(pair_cfgs, pair_params):
    (cfg, _), (params, _) = pair_cfgs, pair_params
    eng = _engine(cfg, params)
    with pytest.raises(ValueError, match="draft_toks"):
        eng.submit(Request(prompt=_prompt(cfg, 8), max_new=4,
                           draft_toks=np.zeros((2, 3), np.int32)))


# -- trained pair: agreement rate (slow — trains draft/target) -------------

@pytest.fixture(scope="module")
def pair():
    from repro.data.tokens import TokenStream, TokenStreamConfig
    from repro.training import optim
    from repro.training.loop import init_state, train

    stream = TokenStream(TokenStreamConfig(vocab_size=TP.ONBOARD.vocab_size,
                                           seq_len=96, batch_size=8))
    out = {}
    for name, cfg, steps in (("draft", TP.ONBOARD, 25),
                             ("target", TP.GROUND, 60)):
        opt = optim.OptimConfig(lr=2e-3, warmup_steps=5, total_steps=steps)
        st = init_state(cfg, opt, max_seq=160)
        st = train(cfg, st, iter(stream), opt, steps=steps, log_every=steps)
        out[name] = (cfg, st.params)
    out["stream"] = stream
    return out


@pytest.mark.slow
def test_speculative_matches_target_greedy(pair):
    dcfg, dparams = pair["draft"]
    tcfg, tparams = pair["target"]
    prompt = pair["stream"].batch(5_000)["tokens"][0, :24]
    want = greedy_generate(tparams, tcfg, prompt, max_new=12)
    got = speculative_generate(dparams, dcfg, tparams, tcfg, prompt,
                               max_new=12, k=4)
    np.testing.assert_array_equal(got.tokens, want)
    assert got.rounds <= 12                     # never worse than greedy
    assert 0.0 <= got.acceptance_rate <= 1.0
    assert got.ledger.get("tokens_produced") == 12


@pytest.mark.slow
def test_speculative_saves_rounds_when_tiers_agree(pair):
    """Trained on the same stream, the tiers agree often enough that
    verify rounds < tokens produced (the communication win)."""
    dcfg, dparams = pair["draft"]
    tcfg, tparams = pair["target"]
    total_rounds = 0
    total_tokens = 0
    for i in (1_000, 2_000, 3_000):
        prompt = pair["stream"].batch(i)["tokens"][0, :32]
        r = speculative_generate(dparams, dcfg, tparams, tcfg, prompt,
                                 max_new=10, k=4)
        total_rounds += r.rounds
        total_tokens += len(r.tokens)
    assert total_rounds < total_tokens
