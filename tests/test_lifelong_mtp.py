"""Lifelong-learning paradigm + the deepseek MTP head."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_reduced_config
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.models import transformer as T
from repro.training import optim
from repro.training.lifelong import (KnowledgeLibrary, LifelongConfig,
                                     lifelong_update)
from repro.training.loop import init_state, train

pytestmark = pytest.mark.slow   # training loops


def _stream(vocab, seed):
    return TokenStream(TokenStreamConfig(vocab_size=vocab, seq_len=64,
                                         batch_size=4, seed=seed))


def _eval_loss(cfg, params, seed):
    batch = {"tokens": jnp.asarray(_stream(cfg.vocab_size, seed)
                                   .batch(9_999)["tokens"])}
    loss, _ = T.loss_fn(params, cfg, batch)
    return float(loss)


def test_lifelong_rehearsal_limits_forgetting():
    cfg = get_reduced_config("smollm-360m")
    lib = KnowledgeLibrary(max_batches_per_task=4)
    opt = optim.OptimConfig(lr=1e-3, warmup_steps=2, total_steps=200)
    state = init_state(cfg, opt, max_seq=64)
    ll = LifelongConfig(steps_per_task=25, rehearsal_ratio=0.5)

    # task A then task B (different markov tables = drifted distribution)
    state = lifelong_update(cfg, state, "taskA", iter(_stream(cfg.vocab_size, 10)),
                            lib, ll=ll)
    loss_a_before = _eval_loss(cfg, state.params, 10)
    state = lifelong_update(cfg, state, "taskB", iter(_stream(cfg.vocab_size, 20)),
                            lib, ll=ll)
    loss_a_after = _eval_loss(cfg, state.params, 10)
    loss_b = _eval_loss(cfg, state.params, 20)

    assert "taskA" in lib.tasks() and "taskB" in lib.tasks()
    assert np.isfinite(loss_b)
    # rehearsal keeps task-A regression small (< 0.5 nats)
    assert loss_a_after < loss_a_before + 0.5
    # snapshots stored per task
    assert set(lib.snapshots) == {"taskA", "taskB"}


def test_mtp_head_trains_and_predicts_t_plus_2():
    cfg = get_reduced_config("deepseek-v3-671b")
    assert cfg.use_mtp
    B, S = 2, 48
    params = T.init_params(jax.random.PRNGKey(0), cfg, max_seq=S)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size)}
    loss, m = T.loss_fn(params, cfg, batch)
    assert float(m["mtp_loss"]) > 0
    assert np.isfinite(float(loss))
    # shape contract: MTP logits predict positions 2..S-1
    _, _, hidden = T.forward(params, cfg, batch, return_hidden=True,
                             remat=False)
    ml = T.mtp_logits(params, cfg, hidden, batch["tokens"])
    assert ml.shape == (B, S - 2, cfg.vocab_size)
    # gradient flows into the MTP params
    g = jax.grad(lambda p: T.loss_fn(p, cfg, batch)[0])(params)
    gmax = max(float(jnp.max(jnp.abs(x.astype(jnp.float32))))
               for x in jax.tree.leaves(g["mtp"]))
    assert gmax > 0


def test_mtp_loss_decreases_with_training():
    cfg = get_reduced_config("deepseek-v3-671b").with_(n_layers=2)
    opt = optim.OptimConfig(lr=2e-3, warmup_steps=3, total_steps=30)
    state = init_state(cfg, opt, max_seq=64)
    state = train(cfg, state, iter(_stream(cfg.vocab_size, 5)), opt,
                  steps=30, log_every=10)
    hist = state.history
    assert hist[-1]["mtp_loss"] < hist[0]["mtp_loss"]
    assert hist[-1]["loss"] < hist[0]["loss"]
