"""Sharding rules: divisibility-aware logical->physical mapping, the
per-preset parameter specs, and the mesh-sharded serving engine.

The sharded-engine tests run wherever >= 2 devices are visible — the CI
``sharded-smoke`` lane forces a 4-device CPU host platform via
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — and skip on the
single-device tier-1 run (where the mesh-keyed jit-cache and accounting
tests still execute against a trivial 1-device mesh)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import get_config, get_reduced_config
from repro.launch import sharding as SH
from repro.launch.mesh import make_serving_mesh
from repro.models import pspec as PS
from repro.models import transformer as T
from repro.serving.batching import Request
from repro.serving.engine import ContinuousEngine
from repro.serving.paging import BlockAllocator, per_device_pool_stats
from repro.serving.scheduler import PreemptiveScheduler


@pytest.fixture
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _abstract_mesh(shape, names):
    """jax >= 0.4.38 takes (shape, names); 0.4.37 takes (name, size) pairs."""
    try:
        return jax.sharding.AbstractMesh(shape, names)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(names, shape)))


def test_pspec_divisibility_fallback(mesh):
    with PS.mesh_rules(mesh):
        # model axis size 1 divides everything -> sharded entries appear
        spec = PS.pspec_for((16, 15), [None, "model"])
        assert spec == P(None, "model")
    big = _abstract_mesh((1, 16), ("data", "model"))
    with PS.mesh_rules(big):
        # 15 heads cannot shard over model=16 -> dropped
        spec = PS.pspec_for((4, 15), [None, "model"])
        assert spec == P(None, None)
        # 32 can
        spec = PS.pspec_for((4, 32), [None, "model"])
        assert spec == P(None, "model")


def test_pspec_duplicate_axis_guard():
    big = _abstract_mesh((2, 2), ("data", "model"))
    with PS.mesh_rules(big, {"a": ("data", "model"), "b": ("data",)}):
        spec = PS.pspec_for((4, 4), ["a", "b"])
        # "b" would reuse "data" -> dropped
        assert spec == P(("data", "model"), None)


def test_shard_noop_without_rules():
    PS.set_mesh_rules(None)
    x = jax.numpy.ones((4, 4))
    assert PS.shard(x, "batch", "model") is x


def test_param_logical_axes_rules():
    import jax.tree_util as jtu
    cfg = get_config("qwen3-moe-30b-a3b")
    from repro.launch.specs import params_specs
    shapes = params_specs(cfg, max_seq=64)
    flat = jtu.tree_flatten_with_path(shapes)[0]
    by_name = {}
    for path, leaf in flat:
        names = SH._path_names(path)
        by_name["/".join(names)] = (path, leaf)
    # expert weights: (L, E, d, f) -> expert on dim 1
    for key, (path, leaf) in by_name.items():
        la = SH.param_logical_axes(path, leaf)
        if key.endswith("moe/w_gate"):
            assert la == [None, "expert", "fsdp", None]
        if key.endswith("moe/w_down"):
            assert la == [None, "expert", None, "fsdp"]
        if key.endswith("attn/w_o"):
            assert la == [None, "model", "fsdp"]
        if key.endswith("router"):
            assert la == [None] * leaf.ndim       # replicated
        if key == "embed":
            assert la == ["model", "fsdp"]


@pytest.mark.parametrize("preset", list(SH.SHARDING_PRESETS))
def test_presets_produce_valid_specs(preset, mesh):
    cfg = get_config("smollm-360m")
    from repro.launch.specs import params_specs
    shapes = params_specs(cfg, max_seq=64)
    specs = SH.params_pspecs(mesh, shapes, SH.SHARDING_PRESETS[preset])
    # every leaf got a NamedSharding on the mesh
    for s in jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "spec")):
        assert s.mesh.shape == mesh.shape


def test_cache_axes_mqa_seq_sharding():
    """granite (kv=1): cache heads cannot shard over model=16, the rule
    falls to sequence sharding."""
    cfg = get_config("granite-34b")
    import jax.numpy as jnp
    leaf = jax.ShapeDtypeStruct((88, 8, 4096, 1, 128), jnp.bfloat16)

    class E:   # fake path entry
        def __init__(self, k):
            self.key = k
    la = SH.cache_logical_axes(cfg, (E("blocks"), E("k")), leaf)
    assert la == [None, "batch", "seq", None, None]
    cfg2 = get_config("zamba2-7b")     # kv=32 -> heads shard
    la2 = SH.cache_logical_axes(cfg2, (E("shared_attn"), E("k")), leaf)
    assert la2 == [None, "batch", None, "model", None]


# ---------------------------------------------------------------------------
# mesh-sharded serving engine
# ---------------------------------------------------------------------------

needs_multi = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="sharded serving needs >= 2 devices (run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _serving_cfg(arch: str):
    """Reduced fp32 config whose KV heads divide a 4-way model axis
    (fp32 so sharded contractions — which reorder reductions — stay
    bit-identical with the single-device run)."""
    over = dict(param_dtype="float32", activation_dtype="float32")
    if arch == "smollm-360m":
        over.update(n_heads=8, n_kv_heads=4, head_dim=32)
    elif arch == "qwen3-moe-30b-a3b":
        over.update(n_kv_heads=4)
    return get_reduced_config(arch).with_(**over)


def _trace(cfg, n=6):
    r = np.random.default_rng(3)
    lens = [5, 17, 9, 30, 12, 3][:n]
    news = [8, 6, 12, 4, 10, 16][:n]
    return [Request(prompt=r.integers(0, cfg.vocab_size,
                                      size=s).astype(np.int32),
                    max_new=m, rid=i, arrival_t=float(i // 2))
            for i, (s, m) in enumerate(zip(lens, news))]


_ENGINE_KW = dict(n_slots=3, max_seq=64, page_size=8,
                  prefill_budget_tokens=16)


def _params_for(cfg):
    return T.init_params(jax.random.PRNGKey(0), cfg, max_seq=64)


def _sweep(arch: str, n=6):
    """Run the same trace through a single-device and a mesh-sharded
    engine; return (single results, sharded results, sharded engine)."""
    cfg = _serving_cfg(arch)
    params = _params_for(cfg)
    e0 = ContinuousEngine(cfg, params, **_ENGINE_KW)
    out0 = e0.run(_trace(cfg, n))
    e1 = ContinuousEngine(cfg, params, mesh=make_serving_mesh(),
                          **_ENGINE_KW)
    out1 = e1.run(_trace(cfg, n))
    return out0, out1, e1


def _assert_token_exact(out0, out1):
    assert out0.keys() == out1.keys()
    for rid in out0:
        np.testing.assert_array_equal(out0[rid].tokens, out1[rid].tokens)


def test_jit_cache_keyed_on_mesh():
    """A sharded and an unsharded engine serving the SAME config must
    not share jitted callables (the sharded trace bakes
    with_sharding_constraint ops in); same-mesh engines must."""
    cfg = _serving_cfg("smollm-360m")
    params = _params_for(cfg)
    mesh = make_serving_mesh()          # trivial (1, 1) on tier-1: still
    #                                     a distinct cache key vs None
    plain = ContinuousEngine(cfg, params, **_ENGINE_KW)
    sharded = ContinuousEngine(cfg, params, mesh=mesh, **_ENGINE_KW)
    sharded2 = ContinuousEngine(cfg, params, mesh=mesh, **_ENGINE_KW)
    assert plain._decode is not sharded._decode
    assert plain._chunk is not sharded._chunk
    assert plain._prefill is not sharded._prefill
    assert sharded._decode is sharded2._decode
    assert sharded._chunk is sharded2._chunk


@needs_multi
def test_sharded_dense_token_exact():
    out0, out1, eng = _sweep("smollm-360m")
    _assert_token_exact(out0, out1)
    s = eng.kv_cache_stats()
    n_dev = len(jax.devices())
    assert s["n_kv_shards"] == n_dev
    assert s["kv_bytes_per_device"] * n_dev == s["kv_cache_bytes"]
    # page axes are never cut: per-device ledger IS the global ledger
    assert s["peak_pages_in_use_per_device"] == s["peak_pages_in_use"]
    # the per-device byte claim against the REAL placement: one
    # addressable shard of each pool leaf
    dev0 = jax.devices()[0]
    real = sum(
        next(sh.data.size for sh in leaf.addressable_shards
             if sh.device == dev0) * leaf.dtype.itemsize
        for leaf in jax.tree.leaves(eng.slots.cache))
    assert real == s["kv_bytes_per_device"]


@pytest.mark.slow
@needs_multi
def test_sharded_moe_token_exact():
    """Expert-parallel MoE serving: experts split over the model axis,
    per-device dispatch slices replacing the global scatter — still
    token-exact with single-device."""
    out0, out1, eng = _sweep("qwen3-moe-30b-a3b", n=4)
    _assert_token_exact(out0, out1)
    s = eng.kv_cache_stats()
    E = eng.cfg.moe.n_experts
    assert s["n_expert_shards"] > 1
    assert s["experts_per_device"] * s["n_expert_shards"] == E


@pytest.mark.slow
@needs_multi
def test_sharded_mla_token_exact():
    """MLA paged serving with the latent rank sharded over the mesh."""
    out0, out1, eng = _sweep("deepseek-v3-671b", n=4)
    _assert_token_exact(out0, out1)
    assert eng.kv_cache_stats()["n_kv_shards"] > 1


@needs_multi
def test_sharded_preempt_spill_resume_token_exact(tmp_path):
    """Preempt -> spill -> resume on the SHARDED engine: snapshots
    device_get token-exact global pages off the head-sharded pool and
    graft back under the mesh; a mid-flight checkpoint restores into a
    fresh sharded engine; a mesh-shape mismatch is refused."""
    cfg = _serving_cfg("smollm-360m")
    params = _params_for(cfg)
    mesh = make_serving_mesh()
    prompt = np.arange(1, 15, dtype=np.int32)
    kw = dict(n_slots=2, max_seq=64, page_size=8, prefill_budget_tokens=4)
    ref = ContinuousEngine(cfg, params, **kw)
    want = list(ref.run([Request(prompt=prompt.copy(),
                                 max_new=6)]).values())[0].tokens

    eng = ContinuousEngine(cfg, params, mesh=mesh, **kw)
    sched = PreemptiveScheduler(eng)
    probe = Request(prompt=prompt.copy(), max_new=6)
    sched.submit(probe)
    sched.step(); sched.step()          # admit + land the first chunks
    (slot,) = [s for s in eng.slots.active_slots()
               if eng.slots.states[s].request.rid == probe.rid]
    sched.preempt(slot)
    sched.submit(Request(prompt=prompt[:5].copy(), max_new=3))
    sched.step(); sched.step()          # filler recycles released pages
    res = sched.run()
    np.testing.assert_array_equal(res[probe.rid].tokens, want)
    assert res[probe.rid].n_preemptions == 1

    # checkpoint mid-flight, restore into a clone of the sharded engine
    eng2 = ContinuousEngine(cfg, params, mesh=mesh, **kw)
    sched2 = PreemptiveScheduler(eng2)
    p2 = Request(prompt=prompt.copy(), max_new=6)
    sched2.submit(p2)
    for _ in range(4):
        sched2.step()
    path = str(tmp_path / "sharded.ckpt")
    sched2.checkpoint(path)
    sched3 = PreemptiveScheduler(eng2.clone_fresh())
    sched3.restore(path)
    np.testing.assert_array_equal(sched3.run()[p2.rid].tokens, want)

    # an unsharded engine must refuse the sharded checkpoint
    with pytest.raises(RuntimeError, match="mesh"):
        PreemptiveScheduler(
            ContinuousEngine(cfg, params, **kw)).restore(path)


def test_per_device_pool_accounting_matches_ledger():
    """Hypothesis invariant: the per-device pool view always agrees
    with the global BlockAllocator ledger — identical page counts
    (page axes are never sharded) and bytes that multiply back to the
    global total when the head dim divides."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(1, 8), st.integers(1, 64), st.data())
    @settings(max_examples=40, deadline=None)
    def run(n_shards, unit, data):
        a = BlockAllocator(24)
        live = []
        for _ in range(data.draw(st.integers(0, 40))):
            if a.available() > 0 and data.draw(st.booleans()):
                a.reserve(1)
                live.extend(a.alloc(1))
            elif live:
                i = data.draw(st.integers(0, len(live) - 1))
                a.release([live.pop(i)])
        page_bytes = unit * n_shards           # divisible head dim
        per_dev = a.n_pages * page_bytes // n_shards
        s = per_device_pool_stats(a, n_shards=n_shards,
                                  kv_bytes_per_device=per_dev)
        assert s["kv_bytes_per_device"] * n_shards == a.n_pages * page_bytes
        assert s["pages_in_use_per_device"] == a.in_use
        assert s["peak_pages_in_use_per_device"] == a.peak_in_use
        assert a.in_use == a.n_pages - len(a._free)
        assert a.peak_in_use >= a.in_use

    run()
