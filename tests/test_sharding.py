"""Sharding rules: divisibility-aware logical->physical mapping and the
per-preset parameter specs."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import get_config
from repro.launch import sharding as SH
from repro.models import pspec as PS


@pytest.fixture
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _abstract_mesh(shape, names):
    """jax >= 0.4.38 takes (shape, names); 0.4.37 takes (name, size) pairs."""
    try:
        return jax.sharding.AbstractMesh(shape, names)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(names, shape)))


def test_pspec_divisibility_fallback(mesh):
    with PS.mesh_rules(mesh):
        # model axis size 1 divides everything -> sharded entries appear
        spec = PS.pspec_for((16, 15), [None, "model"])
        assert spec == P(None, "model")
    big = _abstract_mesh((1, 16), ("data", "model"))
    with PS.mesh_rules(big):
        # 15 heads cannot shard over model=16 -> dropped
        spec = PS.pspec_for((4, 15), [None, "model"])
        assert spec == P(None, None)
        # 32 can
        spec = PS.pspec_for((4, 32), [None, "model"])
        assert spec == P(None, "model")


def test_pspec_duplicate_axis_guard():
    big = _abstract_mesh((2, 2), ("data", "model"))
    with PS.mesh_rules(big, {"a": ("data", "model"), "b": ("data",)}):
        spec = PS.pspec_for((4, 4), ["a", "b"])
        # "b" would reuse "data" -> dropped
        assert spec == P(("data", "model"), None)


def test_shard_noop_without_rules():
    PS.set_mesh_rules(None)
    x = jax.numpy.ones((4, 4))
    assert PS.shard(x, "batch", "model") is x


def test_param_logical_axes_rules():
    import jax.tree_util as jtu
    cfg = get_config("qwen3-moe-30b-a3b")
    from repro.launch.specs import params_specs
    shapes = params_specs(cfg, max_seq=64)
    flat = jtu.tree_flatten_with_path(shapes)[0]
    by_name = {}
    for path, leaf in flat:
        names = SH._path_names(path)
        by_name["/".join(names)] = (path, leaf)
    # expert weights: (L, E, d, f) -> expert on dim 1
    for key, (path, leaf) in by_name.items():
        la = SH.param_logical_axes(path, leaf)
        if key.endswith("moe/w_gate"):
            assert la == [None, "expert", "fsdp", None]
        if key.endswith("moe/w_down"):
            assert la == [None, "expert", None, "fsdp"]
        if key.endswith("attn/w_o"):
            assert la == [None, "model", "fsdp"]
        if key.endswith("router"):
            assert la == [None] * leaf.ndim       # replicated
        if key == "embed":
            assert la == ["model", "fsdp"]


@pytest.mark.parametrize("preset", list(SH.SHARDING_PRESETS))
def test_presets_produce_valid_specs(preset, mesh):
    cfg = get_config("smollm-360m")
    from repro.launch.specs import params_specs
    shapes = params_specs(cfg, max_seq=64)
    specs = SH.params_pspecs(mesh, shapes, SH.SHARDING_PRESETS[preset])
    # every leaf got a NamedSharding on the mesh
    for s in jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "spec")):
        assert s.mesh.shape == mesh.shape


def test_cache_axes_mqa_seq_sharding():
    """granite (kv=1): cache heads cannot shard over model=16, the rule
    falls to sequence sharding."""
    cfg = get_config("granite-34b")
    import jax.numpy as jnp
    leaf = jax.ShapeDtypeStruct((88, 8, 4096, 1, 128), jnp.bfloat16)

    class E:   # fake path entry
        def __init__(self, k):
            self.key = k
    la = SH.cache_logical_axes(cfg, (E("blocks"), E("k")), leaf)
    assert la == [None, "batch", "seq", None, None]
    cfg2 = get_config("zamba2-7b")     # kv=32 -> heads shard
    la2 = SH.cache_logical_axes(cfg2, (E("shared_attn"), E("k")), leaf)
    assert la2 == [None, "batch", None, "model", None]
