"""Per-kernel shape/dtype sweeps: every Pallas kernel (interpret=True on
CPU) against its pure-jnp oracle in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (2, 256, 4, 2, 64),
    (1, 512, 2, 1, 128),
    (2, 256, 8, 8, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 128), (False, 0)])
def test_flash_attention_kernel(B, S, H, Hkv, D, dtype, causal, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=128, block_k=128)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,H,Hkv,D", [(2, 512, 8, 2, 64), (1, 256, 4, 1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kv_len", [1, 100, 512])
def test_decode_attention_kernel(B, S, H, Hkv, D, dtype, kv_len):
    if kv_len > S:
        pytest.skip("kv_len beyond cache")
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = ops.decode_attention(q, k, v, jnp.int32(kv_len), block_k=128)
    want = ref.decode_attention_ref(q, k, v, jnp.int32(kv_len))
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,H,Hkv,D", [(2, 512, 8, 2, 64)])
@pytest.mark.parametrize("S_odd", [100, 129, 500])
def test_decode_attention_kernel_unaligned_cache(B, S, H, Hkv, D, S_odd):
    """Any cache length works: S is padded up to a block_k multiple and
    the pad positions stay masked."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, S_odd, Hkv, D))
    v = jax.random.normal(ks[2], (B, S_odd, Hkv, D))
    lens = jnp.asarray([1, S_odd], jnp.int32)[:B]
    out = ops.decode_attention(q, k, v, lens, block_k=64)
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out, want, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("B,H,Hkv,D,ps,max_bt", [
    (2, 4, 2, 64, 16, 4),
    (3, 8, 1, 32, 8, 6),
    (1, 2, 2, 128, 16, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_kernel(B, H, Hkv, D, ps, max_bt, dtype):
    """Interpret-mode paged kernel vs the ref.py gather reference, with
    shuffled (non-contiguous) block tables and ragged lengths."""
    n_pages = B * max_bt + 1                      # + scratch page 0
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kp = jax.random.normal(ks[1], (n_pages, ps, Hkv, D), dtype)
    vp = jax.random.normal(ks[2], (n_pages, ps, Hkv, D), dtype)
    rng = np.random.default_rng(0)
    bt = jnp.asarray(rng.permutation(np.arange(1, n_pages))
                     .reshape(B, max_bt), jnp.int32)
    lens = jnp.asarray(rng.integers(1, max_bt * ps + 1, B), jnp.int32)
    got = ops.paged_decode_attention(q, kp, vp, bt, lens)
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))
    # cross-check the gather reference itself against the contiguous
    # oracle on the gathered layout
    kg = kp[bt].reshape(B, -1, Hkv, D)
    vg = vp[bt].reshape(B, -1, Hkv, D)
    np.testing.assert_allclose(want.astype(jnp.float32),
                               ref.decode_attention_ref(
                                   q, kg, vg, lens).astype(jnp.float32),
                               atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 256, 2, 32, 16, 64),
    (1, 512, 3, 64, 64, 128),
    (2, 128, 1, 16, 8, 128),   # chunk == S
])
def test_ssm_scan_kernel(B, S, H, P, N, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.uniform(ks[2], (H,), minval=0.0, maxval=1.0))
    Bm = jax.random.normal(ks[3], (B, S, H, N))
    Cm = jax.random.normal(ks[4], (B, S, H, N))
    y, h = ops.ssm_chunk_scan(x, dt, A, Bm, Cm, chunk=chunk)
    y_ref, h_ref = ref.ssm_sequential_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y, y_ref, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(h, h_ref, atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("B,V", [(8, 1000), (16, 4096), (4, 50257)])
@pytest.mark.parametrize("scale", [1.0, 8.0])
def test_conf_gate_kernel(B, V, scale):
    logits = jax.random.normal(KEY, (B, V)) * scale
    got = ops.confidence_gate(logits, block_b=4, block_v=1024)
    want = ref.confidence_gate_ref(logits)
    for k in ("max_prob", "entropy", "margin"):
        np.testing.assert_allclose(got[k], want[k], atol=2e-4, rtol=1e-3)
    assert bool(jnp.all(got["argmax"] == want["argmax"]))


@pytest.mark.parametrize("N,D", [(256, 128), (512, 384), (128, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int8_quant_kernel(N, D, dtype):
    x = jax.random.normal(KEY, (N, D), dtype) * 3.0
    q, s = ops.int8_quantize(x, block_rows=128)
    qr, sr = ref.int8_quantize_ref(x)
    np.testing.assert_allclose(s, sr, rtol=1e-5)
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32) - qr.astype(jnp.int32)))) <= 1
    # reconstruction error bounded by scale/2 (+1 ulp grace)
    rec = ref.int8_dequantize_ref(q, s)
    err = jnp.max(jnp.abs(rec - x.astype(jnp.float32)))
    assert float(err) <= float(jnp.max(s)) * 1.51
