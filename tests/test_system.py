"""End-to-end behaviour tests for the paper's system: the full
EO-pipeline (tile -> filter -> onboard -> gate -> ground) must improve
accuracy over onboard-only while downlinking a fraction of the bytes —
the paper's two headline claims, at test scale."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import classifier as CL
from repro.core.cascade import CascadeConfig, CollaborativeEngine
from repro.core.filtering import filter_tiles
from repro.core.gating import ConfidenceGate
from repro.data import eo

pytestmark = pytest.mark.slow   # trains both tier classifiers


@pytest.fixture(scope="module")
def tiers():
    cfg = eo.EOConfig(cloud_fraction=0.0, dup_fraction=0.0, contrast=0.5,
                      noise=0.24, seed=11)
    tr_t, tr_l, _ = eo.make_tiles(1200, cfg)
    onboard, _ = CL.train_classifier(CL.ONBOARD, tr_t, tr_l, steps=200)
    ground, _ = CL.train_classifier(CL.GROUND, tr_t, tr_l, steps=400)
    test_cfg = eo.EOConfig(**{**cfg.__dict__, "seed": 12})
    te_t, te_l, _ = eo.make_tiles(400, test_cfg)
    return onboard, ground, te_t, te_l


def test_collaborative_improves_accuracy_and_reduces_bytes(tiers):
    onboard, ground, te_t, te_l = tiers
    keep = te_l >= 0
    tiles, labels = te_t[keep], te_l[keep]

    from repro.core.gating import calibrate_threshold
    onboard_fn = lambda b: CL.apply_classifier(onboard, CL.ONBOARD,
                                               jnp.asarray(b))
    probe = np.asarray(ConfidenceGate("max_prob", 1.1).decide(
        jnp.asarray(onboard_fn(tiles)))["confidence"])
    thr = calibrate_threshold(probe, np.ones_like(probe, bool), 0.5)
    engine = CollaborativeEngine(
        onboard_fn,
        lambda b: CL.apply_classifier(ground, CL.GROUND, jnp.asarray(b)),
        CascadeConfig(gate=ConfidenceGate("max_prob", thr)))
    res = engine.run(tiles, item_shape=tiles.shape[1:],
                     ground_available=True)

    acc_collab = float(np.mean(res.predictions == labels))
    onboard_only = engine.run(tiles, item_shape=tiles.shape[1:],
                              ground_available=False)
    acc_onboard = float(np.mean(onboard_only.predictions == labels))

    assert acc_collab > acc_onboard          # paper claim 1 (direction)
    s = res.ledger.summary()
    assert s["bytes_downlinked"] < s["bytes_bentpipe_baseline"]
    assert 0.0 < s["escalation_rate"] < 1.0
    # escalated items were the low-confidence ones
    assert np.all(res.confidence[res.escalated] < thr)
    assert np.all(res.confidence[~res.escalated] >= thr)


def test_filter_then_cascade_pipeline(tiers):
    """Full pipeline on a cloudy scene: filtering removes most tiles
    BEFORE inference; the cascade only pays for survivors."""
    onboard, ground, _, _ = tiers
    tiles, labels, cloudy = eo.make_tiles(300, eo.V1)
    keep, stats = filter_tiles(jnp.asarray(tiles))
    keep = np.asarray(keep)
    assert float(stats["filter_rate"]) > 0.5
    survivors = tiles[keep]
    engine = CollaborativeEngine(
        lambda b: CL.apply_classifier(onboard, CL.ONBOARD, jnp.asarray(b)),
        lambda b: CL.apply_classifier(ground, CL.GROUND, jnp.asarray(b)),
        CascadeConfig())
    res = engine.run(survivors, item_shape=survivors.shape[1:])
    total = res.ledger.get("bytes_downlinked")
    bentpipe_all = tiles.nbytes
    # combined reduction (filter + cascade) is large
    assert total < 0.5 * bentpipe_all


def test_quantized_payload_reduces_escalated_bytes(tiers):
    onboard, ground, te_t, te_l = tiers
    keep = te_l >= 0
    tiles = te_t[keep]
    mk = lambda quant: CollaborativeEngine(
        lambda b: CL.apply_classifier(onboard, CL.ONBOARD, jnp.asarray(b)),
        lambda b: CL.apply_classifier(ground, CL.GROUND, jnp.asarray(b)),
        CascadeConfig(quantize_payload=quant, item_dtype_bytes=4))
    plain = mk(False).run(tiles, item_shape=tiles.shape[1:])
    quant = mk(True).run(tiles, item_shape=tiles.shape[1:])
    assert (quant.ledger.get("bytes_raw_escalated")
            < plain.ledger.get("bytes_raw_escalated"))
    # identical routing decisions
    assert np.array_equal(plain.escalated, quant.escalated)
