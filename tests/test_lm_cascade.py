"""The paper's technique on framework-native LM tiers: the
configs/tiansuan_pair onboard/ground transformers in a collaborative
next-token-prediction cascade (DESIGN.md §2 — the YOLO pair becomes a
(reduced, full) LM pair; the gating math is unchanged)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tiansuan_pair as TP
from repro.core.cascade import CascadeConfig, CollaborativeEngine
from repro.core.gating import ConfidenceGate, calibrate_threshold
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.models import transformer as T
from repro.training import optim
from repro.training.loop import init_state, train

pytestmark = pytest.mark.slow   # trains the onboard/ground LM pair


@pytest.fixture(scope="module")
def lm_tiers():
    stream = TokenStream(TokenStreamConfig(vocab_size=TP.ONBOARD.vocab_size,
                                           seq_len=96, batch_size=8))
    tiers = {}
    for name, cfg, steps in (("onboard", TP.ONBOARD, 30),
                             ("ground", TP.GROUND, 90)):
        opt = optim.OptimConfig(lr=2e-3, warmup_steps=5, total_steps=steps)
        st = init_state(cfg, opt, max_seq=96)
        st = train(cfg, st, iter(stream), opt, steps=steps, log_every=steps)
        tiers[name] = (cfg, st.params, st.history[-1]["loss"])
    return tiers, stream


def test_ground_tier_is_stronger(lm_tiers):
    tiers, _ = lm_tiers
    assert tiers["ground"][2] < tiers["onboard"][2]


def test_lm_collaborative_cascade(lm_tiers):
    tiers, stream = lm_tiers
    ocfg, oparams, _ = tiers["onboard"]
    gcfg, gparams, _ = tiers["ground"]

    eval_batch = stream.batch(10_000)["tokens"]        # held-out
    prefix, target = eval_batch[:, :-1], eval_batch[:, -1]

    def tier_fn(cfg, params):
        def fn(toks):
            logits, _ = T.forward(params, cfg,
                                  {"tokens": jnp.asarray(toks)}, remat=False)
            return np.asarray(logits[:, -1], np.float32)
        return fn

    onboard_fn = tier_fn(ocfg, oparams)
    ground_fn = tier_fn(gcfg, gparams)
    conf = np.asarray(ConfidenceGate("max_prob", 1.1).decide(
        jnp.asarray(onboard_fn(prefix)))["confidence"])
    thr = calibrate_threshold(conf, np.ones_like(conf, bool), 0.6)

    eng = CollaborativeEngine(onboard_fn, ground_fn, CascadeConfig(
        gate=ConfidenceGate("max_prob", thr), item_dtype_bytes=4))
    collab = eng.run(prefix, item_shape=prefix.shape[1:])
    onboard_only = eng.run(prefix, item_shape=prefix.shape[1:],
                           ground_available=False)

    acc_c = float(np.mean(collab.predictions == target))
    acc_o = float(np.mean(onboard_only.predictions == target))
    assert acc_c >= acc_o                     # ground dominates escalations
    s = collab.ledger.summary()
    assert s["bytes_downlinked"] < s["bytes_bentpipe_baseline"]
    assert 0.0 < s["escalation_rate"] <= 0.7 + 1.0 / len(conf)
