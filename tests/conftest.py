import os

# tests must see ONE device (the dry-run, and only the dry-run, forces 512)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
