"""Constellation-scale serving: contact planning + token-exact handover.

Covers ``serving.constellation``:
  * per-step spill -> transmit -> graft exactness: a sequence preempted
    at EVERY decode step, serialized through the checkpoint-store wire
    format, shipped over a framed ARQ lane and grafted on a PEER engine
    must finish with exactly the uninterrupted token stream (dense
    fast; MoE / MLA under ``slow``)
  * the ``ContactPlanner`` capacity discipline (one satellite per
    station, one station per satellite, value-ordered grants)
  * full ``ConstellationScheduler`` replays: handovers actually happen,
    answers are token-exact, every pool and spill store drains —
    including under an injected fault plan (lossy/corrupting ISL frames
    and rotting spill records)
"""
import numpy as np
import pytest

import jax

import repro.models.transformer as T
from helpers import f32_cfg
from repro.config import get_reduced_config
from repro.core.faults import FaultInjector, FaultPlan
from repro.core.link import ContactSchedule, TransmitLane
from repro.serving.batching import Request
from repro.serving.constellation import (ConstellationScheduler,
                                         ContactPlanner, graft_sequence,
                                         pack_request, pack_sequence,
                                         priority_weight)
from repro.serving.engine import ContinuousEngine
from repro.serving.scheduler import PreemptiveScheduler

MAX_SEQ = 64
PAGE = 8
POOL = 12


@pytest.fixture(scope="module")
def cfg():
    return f32_cfg("smollm-360m")


@pytest.fixture(scope="module")
def params(cfg):
    return T.init_params(jax.random.PRNGKey(0), cfg, max_seq=MAX_SEQ)


def _mk_engine(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_seq", MAX_SEQ)
    kw.setdefault("kv_layout", "paged")
    kw.setdefault("page_size", PAGE)
    kw.setdefault("pool_pages", POOL)
    kw.setdefault("prefill_budget_tokens", 16)
    return ContinuousEngine(cfg, params, **kw)


def _assert_drained(eng):
    alloc = getattr(eng.slots, "allocator", None)
    if alloc is not None:
        assert alloc.in_use == 0 and alloc.reserved == 0
        assert len(alloc._free) == alloc.n_pages


def _prompt(cfg, n=6, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)


def _drain(sched):
    while sched.has_work():
        sched.step()
    return sched.results


def _solo_tokens(cfg, params, prompt, max_new):
    eng = _mk_engine(cfg, params)
    rid = eng.submit(Request(prompt=prompt.copy(), max_new=max_new))
    res = _drain(PreemptiveScheduler(eng))
    return np.asarray(res[rid].tokens)


# ---------------------------------------------------------------------------
# per-step spill -> transmit -> graft exactness
# ---------------------------------------------------------------------------

def _handover_sweep(cfg, params, *, max_new=6, interrupts=None,
                    frame_bytes=96, lane_budget=512.0):
    """Interrupt a probe at decode step k, ship it over a framed lane,
    graft it on a PEER scheduler, and require the uninterrupted token
    stream.  One source engine serves the whole sweep (drained between
    iterations) so jit caches stay warm; the destination is rebuilt
    fresh per iteration — a handover always lands on a cold peer pool."""
    prompt = _prompt(cfg)
    want = _solo_tokens(cfg, params, prompt, max_new)
    src_eng = _mk_engine(cfg, params)
    steps = interrupts if interrupts is not None else range(max_new)
    n_grafts = 0
    for k in steps:
        src = PreemptiveScheduler(src_eng)
        rid = src.submit(Request(prompt=prompt.copy(), max_new=max_new))
        for _ in range(k):
            src.step()
        if rid in src.results:          # finished before the interrupt
            continue
        path = str(_handover_sweep._tmp / f"seq_{k}.ckpt")
        queued = next((r for r in src_eng.queue.items() if r.rid == rid),
                      None)
        if queued is not None:          # not admitted yet: no KV to move
            src_eng.queue.take(queued)
            nbytes = pack_request(path, queued)
        else:
            if rid not in src.swapped:
                slot = next(s for s in src_eng.slots.active_slots()
                            if src_eng.slots.states[s].request.rid == rid)
                src.preempt(slot, "spill")
            entry = src.swapped.pop(rid)
            kv = entry.kv
            if kv is None and src.store is not None and rid in src.store:
                kv = src.store.snapshot(rid)
            src.store.drop(rid)
            nbytes = pack_sequence(path, entry, kv, entry.preempted_step)
        assert nbytes > 0
        lane = TransmitLane(frame_bytes=frame_bytes)     # framed, lossless
        lane.enqueue(("seq", rid, 1, path), nbytes)
        ticks = 0
        while not lane.tick(lane_budget):
            ticks += 1
            assert ticks < 10_000
        dst = PreemptiveScheduler(_mk_engine(cfg, params))
        assert graft_sequence(dst, path) == rid
        res = _drain(dst)
        np.testing.assert_array_equal(np.asarray(res[rid].tokens), want)
        _assert_drained(dst.engine)
        _assert_drained(src_eng)
        assert len(dst.store) == 0 and len(src.store) == 0
        n_grafts += 1
    assert n_grafts > 0


def test_handover_exact_every_step_dense(cfg, params, tmp_path):
    _handover_sweep._tmp = tmp_path
    _handover_sweep(cfg, params)


def test_handover_exact_tiny_frames(cfg, params, tmp_path):
    """A KV snapshot split across many small ARQ frames still grafts
    byte-identically (the lane's CRC discipline, not luck)."""
    _handover_sweep._tmp = tmp_path
    _handover_sweep(cfg, params, interrupts=[3], frame_bytes=32,
                    lane_budget=96.0)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "deepseek-v3-671b"])
def test_handover_exact_moe_mla(arch, tmp_path):
    cfg = get_reduced_config(arch).with_(param_dtype="float32",
                                         activation_dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg, max_seq=MAX_SEQ)
    _handover_sweep._tmp = tmp_path
    _handover_sweep(cfg, params, interrupts=[1, 3])


# ---------------------------------------------------------------------------
# contact planner
# ---------------------------------------------------------------------------

def _uniform_windows(n_sats, n_stations, hi=100):
    return {(k, m): [(0, hi)] for k in range(n_sats)
            for m in range(n_stations)}


def test_planner_station_capacity():
    p = ContactPlanner(_uniform_windows(4, 2), 4, 2)
    out = p.assign(0, {k: (10.0, 1.0) for k in range(4)})
    assert len(out) <= 2                       # one lane per station
    assert len(set(out.values())) == len(out)  # one station per satellite


def test_planner_value_ordering():
    # satellite 2 has 3x the priority-weighted backlog: it wins a station
    p = ContactPlanner(_uniform_windows(3, 1), 3, 1)
    out = p.assign(0, {0: (10.0, 1.0), 1: (10.0, 1.0), 2: (30.0, 1.0)})
    assert out == {0: 2}
    # equal value, higher cost loses
    out = p.assign(0, {0: (10.0, 4.0), 1: (10.0, 1.0), 2: (0.0, 1.0)})
    assert out == {0: 1}


def test_planner_zero_value_never_assigned():
    p = ContactPlanner(_uniform_windows(2, 2), 2, 2)
    assert p.assign(0, {0: (0.0, 1.0), 1: (0.0, 1.0)}) == {}


def test_planner_static_home_stations():
    p = ContactPlanner(_uniform_windows(3, 2), 3, 2, policy="static")
    out = p.assign(0, {k: (5.0, 1.0) for k in range(3)})
    # sat 0 -> station 0, sat 1 -> station 1; sat 2's home (0) is taken
    assert out == {0: 0, 1: 1}


def test_planner_respects_windows():
    ws = {(0, 0): [(10, 20)], (0, 1): [], (1, 0): [], (1, 1): [(0, 5)]}
    p = ContactPlanner(ws, 2, 2)
    assert p.assign(0, {0: (5.0, 1.0), 1: (5.0, 1.0)}) == {1: 1}
    assert p.assign(12, {0: (5.0, 1.0), 1: (5.0, 1.0)}) == {0: 0}
    assert p.next_open(0, 0) == 10 and p.next_open(1, 7) is None


def test_step_window_sets_shape_and_determinism():
    sched = ContactSchedule(contact_duration_s=8.0, contacts_per_day=600,
                            seed=5)
    kw = dict(n_satellites=3, n_stations=2, contacts_per_day=[60, 600, 600])
    a = sched.step_window_sets(1.0, 3600.0, **kw)
    b = sched.step_window_sets(1.0, 3600.0, **kw)
    assert a == b and set(a) == {(k, m) for k in range(3) for m in range(2)}
    # distinct pairs draw distinct jitter streams
    assert a[(1, 0)] != a[(2, 0)] or a[(1, 1)] != a[(2, 1)]
    # the sparse plane really is sparse
    assert len(a[(0, 0)]) < len(a[(1, 0)])


def test_priority_weight_floors_at_one():
    assert priority_weight(0) == 1.0
    assert priority_weight(3) == 4.0
    assert priority_weight(-2) == 1.0


# ---------------------------------------------------------------------------
# full constellation replays
# ---------------------------------------------------------------------------

def _constellation(cfg, params, *, n_sats=3, horizon_s=600.0, **kw):
    engines = [_mk_engine(cfg, params) for _ in range(n_sats)]
    ws = kw.pop("window_sets", None)
    if ws is None:
        ws = ContactSchedule(contact_duration_s=6.0, contacts_per_day=2400,
                             seed=3).step_window_sets(
            1.0, horizon_s, n_satellites=n_sats, n_stations=2,
            contacts_per_day=[12, 2400, 2400][:n_sats])
    kw.setdefault("n_stations", 2)
    kw.setdefault("s_per_step", 1.0)
    kw.setdefault("handover_margin_ticks", 16)
    return ConstellationScheduler(engines, window_sets=ws,
                                  horizon_s=horizon_s, **kw)


def _trace(cfg, n=5, seed=0, max_new=6):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        size=6).astype(np.int32),
                    max_new=max_new, arrival_t=0.0) for _ in range(n)]


def _reference_tokens(cfg, params, reqs):
    out = {}
    for r in reqs:
        out[r.rid] = _solo_tokens(cfg, params, r.prompt, r.max_new)
    return out


def _check_replay(cs, rep, reqs, want):
    assert rep.n_handovers > 0
    assert not rep.undelivered
    assert set(rep.tokens) == {r.rid for r in reqs}
    for rid, toks in rep.tokens.items():
        np.testing.assert_array_equal(toks, want[rid])
    for sat in cs.sats:
        _assert_drained(sat.engine)
        assert len(sat.store) == 0
    for lane in [*cs.lanes, *cs.isl]:
        assert len(lane) == 0 and not lane.take_failed()


def test_constellation_handover_token_exact(cfg, params):
    reqs = _trace(cfg)
    want = _reference_tokens(cfg, params, reqs)
    cs = _constellation(cfg, params)
    rep = cs.run([reqs, [], []])
    _check_replay(cs, rep, reqs, want)
    # the loaded, window-poor satellite shipped work out over the ISL
    assert rep.fleet[0].get("bytes_isl", 0) > 0


def test_constellation_handover_under_faults(cfg, params):
    """Lossy + corrupting frames on every lane, plus rotting spill
    records: ARQ re-ships the frames, a corrupt record redoes from
    prefill — the answers are still token-exact and everything drains."""
    reqs = _trace(cfg, seed=2)
    want = _reference_tokens(cfg, params, reqs)
    inj = FaultInjector(FaultPlan(seed=11, frame_loss_rate=0.2,
                                  frame_corrupt_rate=0.15,
                                  spill_corrupt_every=3))
    cs = _constellation(cfg, params, frame_bytes=256, link_max_retries=6,
                        faults=inj, horizon_s=1200.0)
    rep = cs.run([reqs, [], []])
    _check_replay(cs, rep, reqs, want)
    assert inj.n_corruptions_injected > 0
    # every injected frame corruption was DETECTED (CRC), none delivered
    n_det = sum(l["n_corruptions_detected"] for l in
                [*rep.lane_stats, *rep.isl_stats])
    n_silent = sum(l["n_silent_corruptions"] for l in
                   [*rep.lane_stats, *rep.isl_stats])
    assert n_det > 0 and n_silent == 0


def test_constellation_no_handover_without_peer_advantage(cfg, params):
    """Uniform dense windows: nobody's next pass beats the owner's by
    the margin, so no sequence ever moves."""
    ws = {(k, m): [(0, 600)] for k in range(2) for m in range(2)}
    engines = [_mk_engine(cfg, params) for _ in range(2)]
    cs = ConstellationScheduler(engines, window_sets=ws, n_stations=2,
                                s_per_step=1.0, horizon_s=600.0,
                                handover_margin_ticks=16)
    reqs = _trace(cfg, n=3, seed=4)
    rep = cs.run([reqs, []])
    assert rep.n_handovers == 0 and not rep.undelivered


def test_constellation_ownership_is_single(cfg, params):
    """Driven tick by tick: a rid is never owned by two satellites, and
    every planner grant respects station capacity."""
    reqs = _trace(cfg, n=4, seed=1)
    cs = _constellation(cfg, params)
    for k, rs in enumerate([reqs, [], []]):
        for r in rs:
            cs.sats[k].submit(r)
    guard = 0
    while cs.has_work() and cs.clock < cs.horizon_steps:
        cs.tick()
        guard += 1
        assert guard < 5000
        own = cs.ownership()
        assert all(len(sats) == 1 for sats in own.values())
        grants = cs.last_assignment
        assert len(grants) <= cs.n_stations
        assert len(set(grants.values())) == len(grants)


def test_constellation_rejects_contiguous_engines(cfg, params):
    eng = ContinuousEngine(cfg, params, n_slots=2, max_seq=MAX_SEQ,
                           kv_layout="contiguous")
    with pytest.raises(ValueError, match="paged"):
        ConstellationScheduler([eng], window_sets={}, n_stations=1)


def test_constellation_rejects_prefix_cache(cfg, params):
    eng = _mk_engine(cfg, params, prefix_cache=True)
    with pytest.raises(ValueError, match="prefix_cache"):
        ConstellationScheduler([eng], window_sets={}, n_stations=1)
