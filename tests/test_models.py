"""Model-substrate correctness: flash vs naive attention, chunked SSM vs
sequential recurrence, chunked mLSTM vs step decode, and the key serving
invariant — prefill+decode must agree with full-sequence forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ARCH_IDS
from repro.models import transformer as T
from repro.models.attention import chunked_attention
from repro.models.flash import flash_attention
from repro.models.ssm import ssd_chunked
from repro.models.xlstm import mlstm_chunked
from repro.kernels import ref as KREF
from repro.serving.engine import ServingEngine

from helpers import f32_cfg, make_batch

KEY = jax.random.PRNGKey(3)


# ---------------------------------------------------------------------------
# flash attention (jnp) vs naive
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Sq,Skv,causal,window", [
    (256, 256, True, 0), (300, 300, True, 64), (128, 384, False, 0),
])
def test_flash_matches_naive(Sq, Skv, causal, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, Sq, 4, 32))
    k = jax.random.normal(ks[1], (2, Skv, 2, 32))
    v = jax.random.normal(ks[2], (2, Skv, 2, 32))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=128, block_k=128)
    want = chunked_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_flash_gradients_match_naive():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 32))
    k = jax.random.normal(ks[1], (1, 256, 2, 32))
    v = jax.random.normal(ks[2], (1, 256, 2, 32))

    def loss(f):
        return lambda *a: jnp.sum(jnp.tanh(f(*a)))

    g1 = jax.grad(loss(lambda q, k, v: flash_attention(
        q, k, v, causal=True, block_q=128, block_k=128)),
        argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss(lambda q, k, v: chunked_attention(
        q, k, v, causal=True)), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-5)


# ---------------------------------------------------------------------------
# SSM and mLSTM scans vs their sequential definitions
# ---------------------------------------------------------------------------

def test_ssd_chunked_matches_sequential():
    B, S, H, P, N = 2, 256, 2, 16, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.uniform(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, H, N))
    Cm = jax.random.normal(ks[4], (B, S, H, N))
    y, h = ssd_chunked(x, dt, A, Bm, Cm, chunk=64)
    y_ref, h_ref = KREF.ssm_sequential_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y, y_ref, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(h, h_ref, atol=1e-3, rtol=1e-3)


def test_mlstm_chunked_matches_stepwise():
    B, S, H, D = 1, 128, 2, 16
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 2.0

    h_chunk, (C, n, m) = mlstm_chunked(q, k, v, ig, fg, chunk=32)

    # stepwise reference via the same cell math, chunk=1
    h_step, (C2, n2, m2) = mlstm_chunked(q, k, v, ig, fg, chunk=1)
    np.testing.assert_allclose(h_chunk, h_step, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(C, C2, atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# prefill + decode == full forward (per family)
# ---------------------------------------------------------------------------

@pytest.mark.slow   # full per-arch prefill->decode sweep
@pytest.mark.parametrize("arch", [
    "smollm-360m",          # dense GQA, tied embeddings
    "granite-34b",          # MQA + gelu mlp
    "qwen3-moe-30b-a3b",    # MoE + qk-norm
    "deepseek-v3-671b",     # MLA (absorbed decode) + MoE
    "zamba2-7b",            # hybrid mamba + shared attn
    "xlstm-1.3b",           # mLSTM/sLSTM recurrent state
    "qwen2-vl-2b",          # M-RoPE VLM
    "whisper-tiny",         # enc-dec cross attention
])
def test_prefill_decode_consistency(arch):
    cfg = f32_cfg(arch)
    B, S = 2, 24
    eng = ServingEngine.init(cfg, max_seq=64)
    batch = make_batch(cfg, B, S + 1, seed=9)
    tokens = batch.pop("tokens")
    extra = {k: np.asarray(v) for k, v in batch.items()}

    # full forward over S+1 tokens
    full_batch = {"tokens": tokens, **batch}
    # serving-equivalence reference: drop-free MoE routing like the engine
    logits_full, _ = T.forward(eng.params, cfg, full_batch,
                               moe_drop_free=True, remat=False)
    want = logits_full[:, -1]

    # prefill S tokens, decode token S
    pre_batch = {"tokens": tokens[:, :S], **batch}
    logits_pre, cache = eng._prefill(eng.params, pre_batch)
    cache = eng.full_cache(cache, B)
    pos = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    step_logits, _ = eng._decode(eng.params, cache, tokens[:, S:S + 1],
                                 jnp.int32(pos))
    got = step_logits[:, 0]
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)
