"""CollaborativeEngine edge cases: no ground contact, empty batches, and
int8-quantized payload byte accounting — cheap stub tiers, no training."""
import numpy as np
import pytest

from repro.core.cascade import CascadeConfig, CollaborativeEngine
from repro.core.gating import ConfidenceGate
from repro.core.link import payload_bytes_raw, payload_bytes_result

ITEM_SHAPE = (16, 16, 3)


def _logits(n, v=4, seed=0, sharp=False):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, v)).astype(np.float32)
    if sharp:                       # confident: one dominant class
        x[np.arange(n), rng.integers(0, v, n)] += 25.0
    return x


def _engine(onboard_logits, ground_logits=None, **cfg_kw):
    cfg = CascadeConfig(gate=ConfidenceGate("max_prob", 0.99), **cfg_kw)
    ground = (lambda b: ground_logits[:len(b)]) if ground_logits is not None \
        else (lambda b: pytest.fail("ground tier must not be called"))
    return CollaborativeEngine(lambda b: onboard_logits, ground, cfg)


def test_ground_unavailable_forces_zero_escalation():
    """Outside a contact window nothing escalates: predictions are the
    onboard argmax and only compact results are downlinked."""
    n = 12
    logits = _logits(n, seed=1)                       # diffuse: would escalate
    eng = _engine(logits)                             # ground tier traps
    res = eng.run(np.zeros((n, *ITEM_SHAPE), np.uint8), ITEM_SHAPE,
                  ground_available=False)
    assert not res.escalated.any()
    np.testing.assert_array_equal(res.predictions, logits.argmax(-1))
    s = res.ledger.summary()
    assert s["items_escalated"] == 0
    assert s["bytes_raw_escalated"] == 0
    assert s["bytes_downlinked"] == payload_bytes_result(n)


def test_empty_batch():
    logits = _logits(0)
    eng = _engine(logits, ground_logits=logits)
    res = eng.run(np.zeros((0, *ITEM_SHAPE), np.uint8), ITEM_SHAPE)
    assert res.predictions.shape == (0,)
    assert res.escalated.shape == (0,)
    s = res.ledger.summary()
    assert s["items_total"] == 0
    assert s["bytes_downlinked"] == 0
    assert s["escalation_rate"] == 0.0


@pytest.mark.parametrize("dtype_bytes", [1, 4])
def test_quantized_payload_byte_accounting(dtype_bytes):
    """quantize_payload=True charges int8 elements + one 4-byte f32 scale
    per escalated item, independent of the raw dtype width."""
    n = 10
    logits = _logits(n, seed=2)                       # diffuse: all escalate
    ground = _logits(n, seed=3, sharp=True)
    eng = _engine(logits, ground_logits=ground,
                  quantize_payload=True, item_dtype_bytes=dtype_bytes)
    res = eng.run(np.zeros((n, *ITEM_SHAPE), np.float32), ITEM_SHAPE)
    n_esc = int(res.escalated.sum())
    assert n_esc == n                                 # 0.99 threshold
    n_elems = int(np.prod(ITEM_SHAPE))
    want_raw = n_esc * (n_elems + 4)                  # int8 + f32 scale
    s = res.ledger.summary()
    assert s["bytes_raw_escalated"] == want_raw
    assert s["bytes_downlinked"] == want_raw + payload_bytes_result(0)
    # the baseline still pays full-width raw bytes
    assert s["bytes_bentpipe_baseline"] == n * payload_bytes_raw(
        1, ITEM_SHAPE, dtype_bytes)


def test_quantized_never_beats_itself_unquantized():
    """For multi-byte raw dtypes the quantized escalation payload is
    strictly smaller; for uint8 it is 4 bytes/item larger (the scale)."""
    n = 6
    logits = _logits(n, seed=4)
    ground = _logits(n, seed=5, sharp=True)
    bytes_for = {}
    for quant in (False, True):
        eng = _engine(logits, ground_logits=ground,
                      quantize_payload=quant, item_dtype_bytes=4)
        res = eng.run(np.zeros((n, *ITEM_SHAPE), np.float32), ITEM_SHAPE)
        bytes_for[quant] = res.ledger.get("bytes_raw_escalated")
    assert bytes_for[True] < bytes_for[False]
