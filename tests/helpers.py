"""Shared test helpers."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, get_reduced_config


def f32_cfg(arch: str) -> ModelConfig:
    """Reduced config in fp32 for tight numerical comparisons."""
    return get_reduced_config(arch).with_(param_dtype="float32",
                                          activation_dtype="float32")


def make_batch(cfg: ModelConfig, B: int, S: int, seed: int = 0) -> dict:
    key = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    act = jnp.dtype(cfg.activation_dtype)
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), act)
    if cfg.family == "audio":
        batch["audio_frames"] = 0.02 * jax.random.normal(
            key, (B, cfg.n_audio_frames, cfg.d_model), act)
    return batch
