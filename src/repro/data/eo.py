"""Synthetic Earth-Observation data — the DOTA stand-in for the case
study (no real satellite imagery ships with this repo; the generator is
calibrated so the filter/accuracy benchmarks reproduce the paper's
Figure 6/7 regimes).

Frames are (H, W, 3) float32 in [0, 1]:
  * terrain: band-limited noise (sums of random sinusoids);
  * objects: one of ``n_classes`` oriented bright patterns placed per
    tile with class-dependent geometry; difficulty controls contrast;
  * clouds: bright low-texture blobs covering a configurable fraction of
    tiles (southwest-China regime: 80–90% [paper §II]).

Two dataset "versions" mirror DOTA-v1/v2 in the paper's Figure 6: v1 has
heavy cloud cover (~90% redundant) and v2 moderate (~40%).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class EOConfig:
    tile: int = 32
    n_classes: int = 8
    cloud_fraction: float = 0.85     # fraction of CLOUDY tiles (v1-like)
    dup_fraction: float = 0.05       # near-duplicate clear tiles
    contrast: float = 0.9            # object contrast (difficulty)
    noise: float = 0.22              # sensor noise (difficulty)
    seed: int = 0


def _terrain(rng, t):
    yy, xx = np.mgrid[0:t, 0:t].astype(np.float32) / t
    img = np.zeros((t, t), np.float32)
    for _ in range(4):
        fx, fy = rng.uniform(1, 6, 2)
        ph = rng.uniform(0, 2 * np.pi, 2)
        img += rng.uniform(0.05, 0.15) * np.sin(
            2 * np.pi * (fx * xx + ph[0])) * np.sin(
            2 * np.pi * (fy * yy + ph[1]))
    return 0.35 + img


def _object(rng, t, cls, n_classes, contrast):
    """Class-dependent bright pattern: cls encodes (orientation, shape)."""
    yy, xx = np.mgrid[0:t, 0:t].astype(np.float32)
    cy, cx = rng.uniform(0.3 * t, 0.7 * t, 2)
    ang = np.pi * cls / n_classes
    u = (xx - cx) * np.cos(ang) + (yy - cy) * np.sin(ang)
    v = -(xx - cx) * np.sin(ang) + (yy - cy) * np.cos(ang)
    if cls % 2 == 0:                        # bar
        m = (np.abs(u) < t * 0.30) & (np.abs(v) < t * (0.04 + 0.012 * (cls // 2)))
    else:                                   # twin dots
        s = t * (0.05 + 0.015 * (cls // 2))
        d1 = (u - t * 0.12) ** 2 + v ** 2 < s ** 2
        d2 = (u + t * 0.12) ** 2 + v ** 2 < s ** 2
        m = d1 | d2
    return contrast * m.astype(np.float32)


def _cloud(rng, t):
    yy, xx = np.mgrid[0:t, 0:t].astype(np.float32)
    img = np.zeros((t, t), np.float32)
    for _ in range(3):
        cy, cx = rng.uniform(0, t, 2)
        r = rng.uniform(0.4 * t, 0.9 * t)
        img += np.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2) / (r ** 2)))
    return np.clip(0.75 + 0.2 * img, 0, 1.0)


def make_tiles(n: int, cfg: EOConfig = EOConfig()):
    """Returns (tiles (n, t, t, 3) f32, labels (n,) int64 [-1 = cloudy],
    cloudy (n,) bool)."""
    rng = np.random.default_rng(cfg.seed)
    t = cfg.tile
    tiles = np.empty((n, t, t, 3), np.float32)
    labels = np.full((n,), -1, np.int64)
    cloudy = np.zeros((n,), bool)
    dup_pool = []
    for i in range(n):
        r = rng.random()
        if r < cfg.cloud_fraction:
            base = _cloud(rng, t)
            cloudy[i] = True
        else:
            base = _terrain(rng, t)
            cls = int(rng.integers(0, cfg.n_classes))
            base = base + _object(rng, t, cls, cfg.n_classes, cfg.contrast)
            labels[i] = cls
            if rng.random() < cfg.dup_fraction and dup_pool:
                j = dup_pool[int(rng.integers(0, len(dup_pool)))]
                tiles[i] = tiles[j] + rng.normal(
                    0, 0.004, tiles[j].shape).astype(np.float32)
                labels[i] = labels[j]
                continue
            dup_pool.append(i)
        img = np.stack([base] * 3, -1)
        img += rng.normal(0, cfg.noise, img.shape).astype(np.float32) * \
            np.array([1.0, 0.9, 1.1], np.float32)
        tiles[i] = np.clip(img, 0, 1)
    return tiles, labels, cloudy


# dataset "versions" for Figure 6 (DOTA-v1-like vs DOTA-v2-like regimes)
V1 = EOConfig(cloud_fraction=0.86, dup_fraction=0.30, seed=1)
V2 = EOConfig(cloud_fraction=0.33, dup_fraction=0.10, seed=2)
