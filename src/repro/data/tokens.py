"""Synthetic token pipeline: a deterministic, learnable pseudo-language.

Sequences are generated from a fixed random 2nd-order Markov chain with
Zipfian marginals plus periodic copy spans; small models reduce loss
quickly (used by examples/train_100m.py and the training tests), and
the stream is shardable by (host, step) with no state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int = 512
    seq_len: int = 256
    batch_size: int = 8
    seed: int = 0
    branching: int = 4              # candidate successors per bigram


class TokenStream:
    def __init__(self, cfg: TokenStreamConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V, K = cfg.vocab_size, cfg.branching
        # per (prev token) a small successor table with Zipf weights
        self._succ = rng.integers(0, V, size=(V, K))
        w = 1.0 / np.arange(1, K + 1)
        self._w = w / w.sum()

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.batch_size, cfg.seq_len
        out = np.empty((B, S), np.int64)
        tok = rng.integers(0, cfg.vocab_size, size=B)
        for t in range(S):
            out[:, t] = tok
            pick = rng.choice(cfg.branching, size=B, p=self._w)
            tok = self._succ[tok, pick]
        # periodic copy spans (position 3/4 copies the first quarter)
        q = S // 4
        if q > 1:
            out[:, 3 * q:3 * q + q // 2] = out[:, :q // 2]
        return {"tokens": out.astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
