"""jit'd dispatch wrappers: Pallas/Mosaic on TPU, interpret=True (the
kernel body executed in Python) on CPU, with the pure-jnp oracle in
``ref.py`` always available for testing."""
from __future__ import annotations

import functools

import jax

from repro.kernels.conf_gate import confidence_gate_kernel
from repro.kernels.decode_attention import decode_attention_kernel
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.paged_decode_attention import paged_decode_attention_kernel
from repro.kernels.int8_quant import int8_quantize_kernel
from repro.kernels.ssm_scan import ssm_chunk_scan_kernel
from repro.kernels import ref  # noqa: F401  (re-exported for tests)


@functools.lru_cache(maxsize=1)
def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def paged_kernel_ok() -> bool:
    """Whether the Pallas paged-decode kernel may serve this trace: TPU
    only, and only OUTSIDE mesh rules.  Under a serving mesh the KV pool
    is head-sharded and attention must flow through the jnp gather path,
    which GSPMD partitions per shard — the kernel's block-table DMA
    index_map addresses one un-sharded pool and would read a quarter
    pool as if it were whole."""
    if not on_tpu():
        return False
    from repro.models.pspec import current_mesh    # local: no jax device
    # state is touched importing this module (same rule as on_tpu)
    return current_mesh() is None


def flash_attention(q, k, v, *, causal=True, window=0, **kw):
    return flash_attention_kernel(q, k, v, causal=causal, window=window,
                                  interpret=not on_tpu(), **kw)


def decode_attention(q, k, v, kv_len, **kw):
    return decode_attention_kernel(q, k, v, kv_len,
                                   interpret=not on_tpu(), **kw)


def paged_decode_attention(q, k_pages, v_pages, block_tables, kv_len, **kw):
    return paged_decode_attention_kernel(q, k_pages, v_pages, block_tables,
                                         kv_len, interpret=not on_tpu(), **kw)


def ssm_chunk_scan(x, dt, A, Bm, Cm, *, chunk=256, **kw):
    return ssm_chunk_scan_kernel(x, dt, A, Bm, Cm, chunk=chunk,
                                 interpret=not on_tpu(), **kw)


def confidence_gate(logits, **kw):
    return confidence_gate_kernel(logits, interpret=not on_tpu(), **kw)


def int8_quantize(x, **kw):
    return int8_quantize_kernel(x, interpret=not on_tpu(), **kw)
