"""Pallas TPU Mamba2 (SSD) chunked-scan kernel.

One kernel instance owns one (batch, head) pair and walks the chunk
dimension sequentially (minor-most grid axis), carrying the inter-chunk
SSM state (P x N) in fp32 VMEM scratch — the Pallas revisiting pattern
turns the cross-chunk recurrence into scratch persistence, so the whole
selective scan is ONE kernel launch instead of a lax.scan of HBM
round-trips.

Per chunk (all VMEM):
    x:  (Lc, P)   dt: (Lc,)   B, C: (Lc, N)
    intra-chunk: decay matrix from cumsum(log a), quadratic (C B^T ∘ M) x
    inter-chunk: y += C (exp(l_t) * h_prev);  h = exp(l_L) h_prev + hc

VMEM ~ Lc*(P+2N) + Lc^2 + P*N floats; defaults (Lc=256, P=64, N=64)
~0.4 MB.  MXU dims multiples of 64/128 (P, N, Lc).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_s, *,
            n_chunks: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_s[...] = jnp.zeros_like(h_s)

    x = x_ref[0, 0, :, :].astype(F32)           # (Lc, P)
    dt = dt_ref[0, 0, :].astype(F32)            # (Lc,)
    A = a_ref[pl.program_id(1)]                 # this head's decay (negative)
    Bm = b_ref[0, 0, :, :].astype(F32)          # (Lc, N)
    Cm = c_ref[0, 0, :, :].astype(F32)          # (Lc, N)

    loga = dt * A                               # (Lc,)
    cum = jnp.cumsum(loga)                      # l_t
    # intra-chunk quadratic
    S = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # (Lc, Lc)
    decay = cum[:, None] - cum[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    W = jnp.where(tri, S * jnp.exp(decay), 0.0) * dt[None, :]
    y = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())))     # (Lc, P)

    # inter-chunk contribution from the carried state
    h = h_s[...]                                                # (P, N)
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, h, (((1,), (1,)), ((), ())))

    # state update: h = exp(l_L) h + sum_s exp(l_L - l_s) dt_s x_s B_s^T
    wS = jnp.exp(cum[-1] - cum) * dt                            # (Lc,)
    hc = jax.lax.dot_general(x * wS[:, None], Bm,
                             (((0,), (0,)), ((), ())))          # (P, N)
    h_s[...] = jnp.exp(cum[-1]) * h + hc

    y_ref[0, 0, :, :] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _finish():
        hout_ref[0, 0, :, :] = h_s[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_chunk_scan_kernel(x, dt, A, Bm, Cm, *, chunk: int = 256,
                          interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H) post-softplus; A: (H,) negative;
    Bm, Cm: (B,S,H,N).  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    grid = (B, H, nc)

    kernel = functools.partial(_kernel, n_chunks=nc, chunk=chunk)
    # layout: (B, H, S, ...) so the chunk dim tiles cleanly
    xt = x.transpose(0, 2, 1, 3)
    dtt = dt.transpose(0, 2, 1)
    Bt = Bm.transpose(0, 2, 1, 3)
    Ct = Cm.transpose(0, 2, 1, 3)

    y, hout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec(memory_space=pltpu.SMEM),   # A: (H,) scalars
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), F32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), F32)],
        interpret=interpret,
    )(xt, dtt, A, Bt, Ct)
    return y.transpose(0, 2, 1, 3), hout
