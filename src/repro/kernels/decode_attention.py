"""Pallas TPU decode-attention kernel: ONE query token per sequence
against a long KV cache (flash-decoding style).

Grid: (batch, kv_heads, n_kv_blocks) — the kv-block axis is minor-most,
so the online-softmax scratch persists across it.  All ``g = H/Hkv``
query heads of a kv head are processed together as the matmul M dim,
giving the MXU a (g x D) @ (D x block_k) contraction instead of g
vector-matrix products.

BlockSpec tiling (VMEM):
    q:     (1, 1, g*D)        — the g query heads of this kv head
    k, v:  (1, block_k, 1, D) — streamed cache blocks
    out:   (1, 1, g*D)        — written on the last kv block
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            block_k: int, n_kv: int, g: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    D = k_ref.shape[-1]
    q = q_ref[0, 0, :].reshape(g, D).astype(F32) * scale   # (g, D)
    k = k_ref[0, :, 0, :].astype(F32)                      # (bk, D)
    v = v_ref[0, :, 0, :].astype(F32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (g, bk)

    kv_len = len_ref[b]
    kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (g, block_k), 1)
    s = jnp.where(kpos < kv_len, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1)
    acc_s[...] = (acc_s[...] * corr[:, None]
                  + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_s[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finish():
        l_safe = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, 0, :] = (acc_s[...] / l_safe[:, None]).reshape(-1).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention_kernel(q, k, v, kv_len, *, block_k: int = 512,
                            interpret: bool = False):
    """q: (B, H, D); k, v: (B, S, Hkv, D); kv_len: () int32 valid length,
    or (B,) int32 per-sequence valid lengths (continuous batching: every
    slot decodes against its own ragged prefix).  Any cache length works:
    S is zero-padded up to a multiple of block_k — the pad positions sit
    at kpos >= S >= kv_len, so the validity mask already excludes them.
    Returns (B, H, D)."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    block_k = min(block_k, S)
    pad = -S % block_k
    if pad:
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
        S += pad
    n_kv = S // block_k
    grid = (B, Hkv, n_kv)

    kernel = functools.partial(_kernel, block_k=block_k, n_kv=n_kv, g=g,
                               scale=D ** -0.5)
    qg = q.reshape(B, Hkv, g * D)
    kv_len_arr = jnp.broadcast_to(
        jnp.asarray(kv_len, jnp.int32).reshape(-1), (B,))

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),     # kv_len scalar
            pl.BlockSpec((1, 1, g * D), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g * D), lambda b, h, j: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g * D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), F32),
            pltpu.VMEM((g,), F32),
            pltpu.VMEM((g, D), F32),
        ],
        interpret=interpret,
    )(kv_len_arr, qg, k, v)
    return out.reshape(B, H, D)
