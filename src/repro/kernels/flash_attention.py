"""Pallas TPU flash-attention (forward) kernel.

Grid: (batch, q_heads, n_q_blocks, n_kv_blocks).  The TPU grid executes
minor-most dimension sequentially per core, so fp32 VMEM scratch
(running max / denominator / output accumulator) persists across the
kv-block dimension — the online-softmax state machine of
FlashAttention-2 mapped onto the Pallas revisiting pattern.

BlockSpec tiling (per grid step, all VMEM):
    q:   (1, block_q, 1, D)     — revisited across kv blocks
    k,v: (1, block_k, 1, D)     — streamed
    out: (1, block_q, 1, D)     — written on the last kv block
VMEM footprint ~ block_q*D + 2*block_k*D + block_q*block_k floats; the
default (block_q=block_k=512, D=128) is ~0.9 MB — far under the 16 MB
v5e VMEM, leaving room for double buffering.  MXU alignment: all matmul
dims are multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            causal: bool, window: int, block_q: int, block_k: int,
            n_kv: int, scale: float):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, :, 0, :].astype(F32) * scale            # (bq, D)
    k = k_ref[0, :, 0, :].astype(F32)                    # (bk, D)
    v = v_ref[0, :, 0, :].astype(F32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

    qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
    kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1)
    acc_s[...] = (acc_s[...] * corr[:, None]
                  + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_s[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finish():
        l_safe = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_s[...] / l_safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = 512, block_k: int = 512,
                           interpret: bool = False):
    """q: (B, Sq, H, D); k, v: (B, Skv, Hkv, D).  Returns (B, Sq, H, D).

    GQA is handled by the kv BlockSpec index_map (query head h reads kv
    head h // (H // Hkv)) — no repeated kv materialization.
    """
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv)
    n_q, n_kv = Sq // block_q, Skv // block_k
    grid = (B, H, n_q, n_kv)

    kernel = functools.partial(
        _kernel, causal=causal, window=window, block_q=block_q,
        block_k=block_k, n_kv=n_kv, scale=D ** -0.5)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, i, j: (b, j, h // g, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, i, j: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), F32),               # running max
            pltpu.VMEM((block_q,), F32),               # denominator
            pltpu.VMEM((block_q, D), F32),             # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
