"""Pallas TPU row-wise absmax int8 quantization kernel.

Beyond-paper payload compression: escalated offloads (low-confidence
items shipped satellite -> ground) carry activations/embeddings; int8
with per-row scales cuts the downlink bytes 2x vs bf16 / 4x vs fp32 at
negligible accuracy cost (EXPERIMENTS.md §Perf).

Grid: (n_row_blocks,).  One VMEM tile holds (block_rows, D) — absmax
reduce and scaled round in a single pass, no HBM round-trip between the
two.  D is padded to a lane multiple (128) by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(F32)                      # (bb, D)
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def int8_quantize_kernel(x, *, block_rows: int = 256,
                         interpret: bool = False):
    """x: (N, D) -> (q int8 (N, D), scale f32 (N,))."""
    N, D = x.shape
    block_rows = min(block_rows, N)
    assert N % block_rows == 0, (N, block_rows)
    grid = (N // block_rows,)
    q, s = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((N, D), jnp.int8),
                   jax.ShapeDtypeStruct((N,), F32)],
        interpret=interpret,
    )(x)
    return q, s
