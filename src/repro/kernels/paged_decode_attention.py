"""Pallas TPU paged decode-attention kernel: ONE query token per
sequence against a paged KV cache (flash-decoding online softmax over
block-table-indexed pages).

The KV pool is ``(n_pages, page_size, Hkv, D)`` — a sequence's keys live
in the pages named by its block table, page ``j`` holding absolute
positions ``[j*page_size, (j+1)*page_size)``.  The block tables and
per-sequence lengths are **scalar-prefetched**
(``pltpu.PrefetchScalarGridSpec``) so the kv BlockSpec ``index_map`` can
dereference the table: grid step ``(b, h, j)`` DMAs page
``block_tables[b, j]`` straight from the pool — the gather happens in
the DMA engine, never materializing a contiguous copy of the sequence.

Grid: (batch, kv_heads, max_pages) — the page axis is minor-most, so the
online-softmax scratch (running max / denominator / accumulator)
persists across it, exactly like the contiguous ``decode_attention``
kernel.  Table entries past ``ceil(kv_len/page_size)`` point at the
scratch page 0; their positions fail the ``kpos < kv_len`` mask, so
stale data there (or in a freshly allocated page's tail) is never read —
the paged layout's overwrite-before-read guarantee.

BlockSpec tiling (VMEM):
    q:     (1, 1, g*D)            — the g = H/Hkv query heads per kv head
    k, v:  (1, page_size, 1, D)   — one streamed KV page
    out:   (1, 1, g*D)            — written on the last page
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            page_size: int, n_pages_grid: int, g: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    D = k_ref.shape[-1]
    q = q_ref[0, 0, :].reshape(g, D).astype(F32) * scale   # (g, D)
    k = k_ref[0, :, 0, :].astype(F32)                      # (ps, D)
    v = v_ref[0, :, 0, :].astype(F32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (g, ps)

    kv_len = len_ref[b]
    kpos = (j * page_size
            + jax.lax.broadcasted_iota(jnp.int32, (g, page_size), 1))
    s = jnp.where(kpos < kv_len, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1)
    acc_s[...] = (acc_s[...] * corr[:, None]
                  + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_s[...] = m_new

    @pl.when(j == n_pages_grid - 1)
    def _finish():
        l_safe = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, 0, :] = (acc_s[...] / l_safe[:, None]).reshape(-1).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_kernel(q, k_pages, v_pages, block_tables, kv_len,
                                  *, interpret: bool = False):
    """q: (B, H, D); k_pages, v_pages: (n_pages, page_size, Hkv, D);
    block_tables: (B, max_pages) int32 page ids (unused entries 0);
    kv_len: () or (B,) int32 valid positions per sequence.
    Returns (B, H, D)."""
    B, H, D = q.shape
    page_size, Hkv = k_pages.shape[1], k_pages.shape[2]
    g = H // Hkv
    max_pages = block_tables.shape[1]
    grid = (B, Hkv, max_pages)

    kernel = functools.partial(_kernel, page_size=page_size,
                               n_pages_grid=max_pages, g=g,
                               scale=D ** -0.5)
    qg = q.reshape(B, Hkv, g * D)
    bt = jnp.asarray(block_tables, jnp.int32)
    kv_len_arr = jnp.broadcast_to(
        jnp.asarray(kv_len, jnp.int32).reshape(-1), (B,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,            # block tables + kv lengths
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g * D), lambda b, h, j, bt, kl: (b, h, 0)),
            # the paged gather: page j of sequence b via its block table
            pl.BlockSpec((1, page_size, 1, D),
                         lambda b, h, j, bt, kl: (bt[b, j], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, D),
                         lambda b, h, j, bt, kl: (bt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g * D),
                               lambda b, h, j, bt, kl: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), F32),                 # running max
            pltpu.VMEM((g,), F32),                 # denominator
            pltpu.VMEM((g, D), F32),               # output accumulator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g * D), q.dtype),
        interpret=interpret,
    )(bt, kv_len_arr, qg, k_pages, v_pages)
    return out.reshape(B, H, D)
