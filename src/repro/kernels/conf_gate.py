"""Pallas TPU confidence-gate kernel — THE paper's gating primitive.

The satellite tier decides per item whether to downlink its own result
or escalate to the ground tier, based on posterior confidence (paper
§IV).  For LM tiers the posterior lives over vocabularies up to 152k:
computing softmax -> max/entropy/margin naively is 3+ HBM passes over
(B, V) logits.  This kernel fuses everything into ONE streaming pass:

    one grid step = one (row-block, vocab-block) tile in VMEM; online
    running (max1, max2, argmax, sumexp, sum x*exp) scratch across the
    vocab dimension; on the last vocab block it emits
        max_prob = exp(m1 - lse)
        entropy  = (m + log l) - sx / l
        margin   = exp(m1 - lse) - exp(m2 - lse)
        argmax

Grid: (n_row_blocks, n_vocab_blocks); vocab minor-most so scratch
persists.  BlockSpec: logits (block_b, block_v) VMEM tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(x_ref, mp_ref, ent_ref, mar_ref, am_ref,
            m1_s, m2_s, am_s, l_s, sx_s, *,
            block_v: int, n_v: int, vocab: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m1_s[...] = jnp.full_like(m1_s, NEG_INF)
        m2_s[...] = jnp.full_like(m2_s, NEG_INF)
        am_s[...] = jnp.zeros_like(am_s)
        l_s[...] = jnp.zeros_like(l_s)
        sx_s[...] = jnp.zeros_like(sx_s)

    x = x_ref[...].astype(F32)                               # (bb, bv)
    vpos = j * block_v + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    x = jnp.where(vpos < vocab, x, NEG_INF)

    # block-local top-2
    bm1 = jnp.max(x, axis=-1)
    bam = j * block_v + jnp.argmax(x, axis=-1).astype(jnp.int32)
    x2 = jnp.where(vpos == bam[:, None], NEG_INF, x)
    bm2 = jnp.max(x2, axis=-1)

    m1p, m2p, amp = m1_s[...], m2_s[...], am_s[...]
    m1n = jnp.maximum(m1p, bm1)
    # new second max: the best of (old pair, block pair) minus the new max
    m2n = jnp.maximum(jnp.maximum(m2p, bm2), jnp.minimum(m1p, bm1))
    amn = jnp.where(bm1 > m1p, bam, amp)

    # online softmax stats
    bl = jnp.sum(jnp.exp(x - m1n[:, None]), axis=-1)
    bsx = jnp.sum(jnp.where(x > NEG_INF / 2,
                            x * jnp.exp(x - m1n[:, None]), 0.0), axis=-1)
    corr = jnp.exp(m1p - m1n)
    l_s[...] = l_s[...] * corr + bl
    sx_s[...] = sx_s[...] * corr + bsx
    m1_s[...], m2_s[...], am_s[...] = m1n, m2n, amn

    @pl.when(j == n_v - 1)
    def _finish():
        m1, m2 = m1_s[...], m2_s[...]
        l = jnp.maximum(l_s[...], 1e-30)
        lse = m1 + jnp.log(l)
        mp = jnp.exp(m1 - lse)
        mp2 = jnp.exp(m2 - lse)
        mp_ref[...] = mp
        ent_ref[...] = lse - sx_s[...] / l          # H = lse - E[x]
        mar_ref[...] = mp - mp2
        am_ref[...] = am_s[...]


@functools.partial(jax.jit, static_argnames=("block_b", "block_v",
                                             "interpret"))
def confidence_gate_kernel(logits, *, block_b: int = 8, block_v: int = 2048,
                           interpret: bool = False):
    """logits: (B, V) -> dict(max_prob, entropy, margin, argmax)."""
    B, V = logits.shape
    block_b = min(block_b, B)
    block_v = min(block_v, -(-V // 128) * 128)
    assert B % block_b == 0, (B, block_b)
    n_b = B // block_b
    Vp = -(-V // block_v) * block_v
    if Vp != V:
        logits = jnp.pad(logits, ((0, 0), (0, Vp - V)),
                         constant_values=NEG_INF)
    n_v = Vp // block_v
    grid = (n_b, n_v)

    kernel = functools.partial(_kernel, block_v=block_v, n_v=n_v, vocab=V)
    out_shapes = [jax.ShapeDtypeStruct((B,), F32) for _ in range(3)] + \
                 [jax.ShapeDtypeStruct((B,), jnp.int32)]
    row_spec = pl.BlockSpec((block_b,), lambda i, j: (i,))
    mp, ent, mar, am = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, block_v), lambda i, j: (i, j))],
        out_specs=[row_spec] * 4,
        out_shape=out_shapes,
        scratch_shapes=[pltpu.VMEM((block_b,), F32)] * 2
        + [pltpu.VMEM((block_b,), jnp.int32)]
        + [pltpu.VMEM((block_b,), F32)] * 2,
        interpret=interpret,
    )(logits)
    return {"max_prob": mp, "entropy": ent, "margin": mar, "argmax": am}
