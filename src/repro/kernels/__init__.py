"""Pallas TPU kernels for the compute hot spots.

Each kernel lives in ``<name>.py`` (pl.pallas_call + explicit BlockSpec
VMEM tiling), has a pure-jnp oracle in ``ref.py`` and a jit'd dispatch
wrapper in ``ops.py`` (interpret=True off-TPU, Mosaic on TPU)."""
