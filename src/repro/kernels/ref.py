"""Pure-jnp oracles for every Pallas kernel.  These are the ground truth
the kernels are swept against (tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,S,H,D); k,v: (B,S,Hkv,D) — plain softmax attention."""
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Sq, Hkv, g, D).astype(F32) * D ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(F32))
    qpos = jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(F32))
    return o.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def decode_attention_ref(q, k, v, kv_len):
    """q: (B,H,D); k,v: (B,S,Hkv,D); kv_len: scalar valid length or (B,)
    per-sequence valid lengths."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, D).astype(F32) * D ** -0.5
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k.astype(F32))
    kl = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (B,))
    mask = jnp.arange(S)[None, :] < kl[:, None]          # (B, S)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(F32))
    return o.reshape(B, H, v.shape[-1]).astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, kv_len):
    """Gather reference for the paged decode kernel.  q: (B,H,D);
    k_pages/v_pages: (n_pages, page_size, Hkv, D); block_tables:
    (B, max_pages) int32 page ids (positions [j*ps, (j+1)*ps) of
    sequence b live in page block_tables[b, j]); kv_len: () or (B,)
    valid positions.  Gathers the tables back into position order and
    delegates to the contiguous oracle."""
    B = q.shape[0]
    kg = k_pages[block_tables]              # (B, max_pages, ps, Hkv, D)
    vg = v_pages[block_tables]
    kg = kg.reshape(B, -1, *k_pages.shape[2:])
    vg = vg.reshape(B, -1, *v_pages.shape[2:])
    return decode_attention_ref(q, kg, vg, kv_len)


def ssm_chunk_scan_ref(x, dt, A, Bm, Cm, chunk):
    """Mamba2 SSD oracle — delegates to the model implementation (itself
    validated against a step-by-step sequential scan in tests)."""
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, A, Bm, Cm, chunk)


def ssm_sequential_ref(x, dt, A, Bm, Cm):
    """Step-by-step SSM recurrence (the definitional ground truth).
    x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,H,N)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * A)                        # (B,H)
        h = h * decay[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dtt, bt, xt)
        y = jnp.einsum("bhn,bhpn->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((B, H, P, N), F32)
    xs = (x.swapaxes(0, 1).astype(F32), dt.swapaxes(0, 1).astype(F32),
          Bm.swapaxes(0, 1).astype(F32), Cm.swapaxes(0, 1).astype(F32))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1), h


def confidence_gate_ref(logits):
    """Fused confidence metrics over vocab logits (B, V) in fp32:
    returns dict(max_prob, entropy, margin, argmax)."""
    x = logits.astype(F32)
    p = jax.nn.softmax(x, axis=-1)
    top2 = jax.lax.top_k(p, 2)[0]
    ent = -jnp.sum(jnp.where(p > 0, p * jnp.log(jnp.maximum(p, 1e-30)), 0.0),
                   axis=-1)
    return {
        "max_prob": jnp.max(p, axis=-1),
        "entropy": ent,
        "margin": top2[..., 0] - top2[..., 1],
        "argmax": jnp.argmax(x, axis=-1).astype(jnp.int32),
    }


def int8_quantize_ref(x):
    """Row-wise absmax int8 quantization.  x: (N, D)."""
    amax = jnp.max(jnp.abs(x.astype(F32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(F32) / scale[:, None]), -127, 127)
    return q.astype(jnp.int8), scale


def int8_dequantize_ref(q, scale):
    return q.astype(F32) * scale[:, None]
