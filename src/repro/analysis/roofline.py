"""Roofline analysis over dry-run results (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh):
    compute    = HLO_FLOPs / (chips x 197 TF/s bf16)
    memory     = HLO_bytes / (chips x 819 GB/s HBM)
    collective = collective_bytes / (chips x 50 GB/s ICI)
(all terms per-device — post-partitioning HLO shapes are per-device, so
no extra division by chips is applied to the numerators).

Also derives MODEL_FLOPS = 6*N*D (6*N_active*D for MoE; D = tokens
processed) and the usefulness ratio MODEL/HLO which exposes remat,
causal-masking waste and sharding-replication waste.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Optional

from repro.config import INPUT_SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_per_dev: float
    hlo_flops_per_dev: float
    useful_ratio: float
    note: str = ""

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def tokens_for(shape_name: str) -> int:
    s = INPUT_SHAPES[shape_name]
    if s.kind == "decode":
        return s.global_batch            # one new token per sequence
    return s.seq_len * s.global_batch


def model_flops(res: dict) -> float:
    """6*N*D global; backward doubles-ish -> 6ND for train already
    includes fwd+bwd by convention; inference uses 2*N*D."""
    n = res["params_active"]
    d = tokens_for(res["shape"])
    mult = 6.0 if res["kind"] == "train" else 2.0
    return mult * n * d


def improvement_note(row: "RooflineRow", res: dict) -> str:
    if row.dominant == "collective":
        return ("reduce all-gather/all-reduce volume: shard MoE dispatch "
                "with all-to-all instead of gather, or move FSDP gathers "
                "to reduce-scatter schedule")
    if row.dominant == "memory":
        if res["kind"] == "decode":
            return ("decode is cache-bandwidth bound: shrink KV bytes "
                    "(MLA-style latent cache / int8 KV) or batch more "
                    "sequences per weight read")
        return ("fuse attention/norm chains into Pallas kernels so score "
                "blocks stay in VMEM; cast gate weights to bf16")
    return ("increase arithmetic intensity: larger per-device batch or "
            "wider TP sharding of heads")


def load_rows(result_dir: str) -> list:
    rows = []
    for f in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        res = json.load(open(f))
        if res.get("skipped") or "error" in res:
            continue
        n_dev = res["n_devices"]
        flops = res["flops_per_device"]
        byts = res["bytes_per_device"]
        link = res["collectives"]["total_link_bytes"]
        ct = flops / PEAK_FLOPS_BF16
        mt = byts / HBM_BW
        lt = link / ICI_BW
        dom = max((("compute", ct), ("memory", mt), ("collective", lt)),
                  key=lambda x: x[1])[0]
        mf = model_flops(res) / n_dev
        row = RooflineRow(
            arch=res["arch"], shape=res["shape"], mesh=res["mesh"],
            compute_s=ct, memory_s=mt, collective_s=lt, dominant=dom,
            model_flops_per_dev=mf, hlo_flops_per_dev=flops,
            useful_ratio=mf / flops if flops else float("nan"))
        row.note = improvement_note(row, res)
        rows.append(row)
    return rows


def to_markdown(rows: list) -> str:
    out = ["| arch | shape | mesh | compute s | memory s | collective s "
           "| bound | MODEL/HLO | what moves the bound |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.3f} "
            f"| {r.memory_s:.3f} | {r.collective_s:.3f} | **{r.dominant}** "
            f"| {r.useful_ratio:.3f} | {r.note} |")
    return "\n".join(out)
