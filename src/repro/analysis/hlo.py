"""Trip-count-aware static analysis of compiled (partitioned) HLO text.

``xla::HloCostAnalysis`` (behind ``compiled.cost_analysis()``) visits a
``while`` body ONCE, so a 61-layer scanned transformer is undercounted
~61x — and collectives inside the scan body are missed entirely by a
naive text grep.  This module parses the HLO module into computations,
resolves the call graph (while / fusion / call / conditional), reads
loop trip counts from ``backend_config={"known_trip_count"...}`` (with
the loop-condition constant as fallback), and accumulates per-device:

  * flops            — 2*|out|*K for dots, |out| for elementwise/reduce
  * bytes            — operand + result bytes of materializing ops
                       (fusion-boundary HBM-traffic model)
  * collective bytes — result bytes per collective kind, multiplied
                       through loop trip counts

Shapes in post-partitioning HLO are per-device, so all numbers are
per-device too.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|[a-z][a-z0-9]*\[[0-9,]*\]\S*)"
    r"\s+([\w\-]+)\((.*)$")
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "exponential", "log", "negate", "power", "rsqrt", "sqrt", "tanh",
    "logistic", "sign", "floor", "ceil", "compare", "select", "and", "or",
    "not", "xor", "convert", "expm1", "log1p", "cosine", "sine", "atan2",
    "remainder", "clamp", "round-nearest-even", "erf", "exponential-minus-one",
}
_ZERO_COST = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
              "after-all", "partition-id", "replica-id", "opt-barrier",
              "get-dimension-size", "copy-start", "copy-done", "domain"}
_MOVERS = {"copy", "dynamic-slice", "dynamic-update-slice", "slice",
           "broadcast", "concatenate", "pad", "transpose", "reverse",
           "gather", "scatter", "reshape", "iota", "sort",
           "dynamic-reshape", "rng", "rng-bit-generator"}
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def shape_bytes(shape_str: str) -> int:
    return sum(_numel(dims) * _DTYPE_BYTES.get(dt, 4)
               for dt, dims in _SHAPE_RE.findall(shape_str))


def shape_numel(shape_str: str) -> int:
    return sum(_numel(dims) for _, dims in _SHAPE_RE.findall(shape_str))


@dataclass
class Op:
    name: str
    shape: str
    kind: str
    rest: str

    def operands(self) -> List[str]:
        # operand list = rest up to the matching close paren (first level)
        depth = 1
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return _OPERAND_RE.findall(self.rest[:i])
        return _OPERAND_RE.findall(self.rest)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{"):
                m = _COMP_HDR.match(stripped)
                if m:
                    cur = Computation(m.group(1))
                    if stripped.startswith("ENTRY"):
                        entry = cur.name
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.ops.append(op)
            cur.shapes[op.name] = op.shape
    return comps, entry


def _dot_flops(op: Op, operand_shape: Optional[str]) -> int:
    out = shape_numel(op.shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    if not m or not operand_shape:
        return 2 * out
    sh = _SHAPE_RE.findall(operand_shape)
    if not sh:
        return 2 * out
    lhs_dims = [int(x) for x in sh[0][1].split(",") if x]
    contract = 1
    for i in m.group(1).split(","):
        if i and int(i) < len(lhs_dims):
            contract *= lhs_dims[int(i)]
    return 2 * out * contract


class Analyzer:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._memo: Dict[str, dict] = {}

    def trip_count(self, op: Op) -> int:
        m = _TRIP_RE.search(op.rest)
        if m:
            return int(m.group(1))
        cm = _COND_RE.search(op.rest)
        if cm and cm.group(1) in self.comps:
            consts = []
            for o in self.comps[cm.group(1)].ops:
                consts += [int(x) for x in
                           _CONST_RE.findall(o.kind + "(" + o.rest)]
            if consts:
                return max(consts)
        return 1

    def _operand_bytes(self, comp: Computation, op: Op,
                       trip_hint: int = 1) -> int:
        """Sum operand bytes.  Scan-stacked loop-state operands (leading
        dim == the enclosing loop's trip count) are consumed via an
        in-fusion dynamic-slice — one layer's slice per iteration — so
        they are charged at slice size, not stack size."""
        total = 0
        for name in op.operands():
            sh = comp.shapes.get(name)
            if not sh:
                continue
            b = shape_bytes(sh)
            if trip_hint > 1:
                m = _SHAPE_RE.search(sh)
                if m:
                    dims = [d for d in m.group(2).split(",") if d]
                    if dims and int(dims[0]) == trip_hint:
                        b //= trip_hint
            total += b
        return total

    def analyze(self, comp_name: Optional[str] = None,
                trip_hint: int = 1) -> dict:
        comp_name = comp_name or self.entry
        memo_key = (comp_name, trip_hint)
        if memo_key in self._memo:
            return self._memo[memo_key]
        comp = self.comps.get(comp_name)
        acc = {"flops": 0, "bytes": 0,
               "coll": {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}}
        self._memo[memo_key] = acc
        if comp is None:
            return acc
        for op in comp.ops:
            kind = op.kind
            base = kind[:-6] if kind.endswith("-start") else kind
            if base in COLLECTIVES:
                if kind.endswith("-done"):
                    continue
                acc["coll"][base]["count"] += 1
                acc["coll"][base]["bytes"] += shape_bytes(op.shape)
                acc["bytes"] += shape_bytes(op.shape)
                continue
            if kind == "while":
                body = _CALLS_RE.search(op.rest)
                trips = self.trip_count(op)
                if body:
                    self._merge(acc, self.analyze(body.group(1),
                                                  trip_hint=trips), trips)
                continue
            if kind == "conditional":
                m = _BRANCHES_RE.search(op.rest)
                if m:
                    subs = [self.analyze(b.strip().lstrip("%"))
                            for b in m.group(1).split(",")]
                    if subs:
                        self._merge(acc, max(subs, key=lambda s: s["flops"]), 1)
                continue
            if kind in ("fusion", "call", "map", "reduce", "reduce-window",
                        "select-and-scatter", "custom-call"):
                body = _CALLS_RE.search(op.rest)
                if body:
                    sub = self.analyze(body.group(1))
                    # inner flops/collectives count; inner bytes do not
                    acc["flops"] += sub["flops"]
                    for k in COLLECTIVES:
                        acc["coll"][k]["count"] += sub["coll"][k]["count"]
                        acc["coll"][k]["bytes"] += sub["coll"][k]["bytes"]
                acc["bytes"] += shape_bytes(op.shape) + self._operand_bytes(
                    comp, op, trip_hint)
                continue
            if kind == "dot":
                ops_ = op.operands()
                lhs_shape = comp.shapes.get(ops_[0]) if ops_ else None
                acc["flops"] += _dot_flops(op, lhs_shape)
                acc["bytes"] += shape_bytes(op.shape) + self._operand_bytes(
                    comp, op, trip_hint)
                continue
            if kind == "convolution":
                acc["flops"] += 2 * shape_numel(op.shape)
                acc["bytes"] += shape_bytes(op.shape) + self._operand_bytes(
                    comp, op, trip_hint)
                continue
            if kind in ELEMENTWISE:
                # optimal-fusion HBM model: a standalone elementwise op on
                # the CPU backend would be fused into its consumer on TPU —
                # count the result write only
                acc["flops"] += shape_numel(op.shape)
                acc["bytes"] += shape_bytes(op.shape)
                continue
            if kind == "dynamic-update-slice":
                # in-place on TPU: traffic = the updated slice, not the buffer
                ops_ = op.operands()
                upd = comp.shapes.get(ops_[1]) if len(ops_) > 1 else None
                acc["bytes"] += 2 * shape_bytes(upd) if upd else shape_bytes(op.shape)
                continue
            if kind == "copy":
                acc["bytes"] += 2 * shape_bytes(op.shape)   # read + write
                continue
            if kind in _MOVERS:
                acc["bytes"] += shape_bytes(op.shape)       # result write
                continue
            # _ZERO_COST and anything unknown: free
        return acc

    @staticmethod
    def _merge(acc, sub, mult):
        acc["flops"] += sub["flops"] * mult
        acc["bytes"] += sub["bytes"] * mult
        for k in COLLECTIVES:
            acc["coll"][k]["count"] += sub["coll"][k]["count"] * mult
            acc["coll"][k]["bytes"] += sub["coll"][k]["bytes"] * mult


def analyze_hlo(text: str) -> dict:
    res = Analyzer(text).analyze()
    res["total_link_bytes"] = sum(
        v["bytes"] * (2 if k == "all-reduce" else 1)
        for k, v in res["coll"].items())
    return res
