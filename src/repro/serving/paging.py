"""Paged KV-cache bookkeeping for the continuous engine.

A ``BlockAllocator`` owns a global pool of fixed-size KV pages.  Each
active sequence holds a growable block table (list of page ids); pages
are handed out on admission (prompt pages) and during decode (one page
every ``page_size`` generated tokens) and returned to the free list on
eviction.  Memory therefore scales with ``sum_i ceil(len_i/page_size)``
instead of ``n_slots * max_seq``.

Admission uses a *reservation* discipline so decode can never stall on
an empty pool: a request is only admitted when its worst-case lifetime
page count (``ceil((prompt + max_new - 1)/page_size)``) can be reserved
up front.  Pages are still allocated lazily against that reservation,
and any unused reservation is released on eviction.

Page id 0 is a scratch page: inactive slots (and unused block-table
entries) point at it, so their dummy decode writes land somewhere no
live sequence ever reads.  The allocator hands out ids ``1..n_pages``.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional

import jax
import numpy as np

try:                                   # optional dep, same policy as
    import zstandard as zstd           # repro.checkpoint.store
except ImportError:                    # pragma: no cover
    zstd = None

SCRATCH_PAGE = 0


def pages_for(n_positions: int, page_size: int) -> int:
    """Number of pages covering ``n_positions`` cache positions."""
    return max(0, -(-n_positions // page_size))


def default_pool_pages(n_slots: int, max_seq: int, page_size: int,
                       frac: float = 0.75) -> int:
    """Default pool sizing: ``frac`` of the contiguous layout's
    ``n_slots * max_seq`` positions, but never smaller than one
    worst-case request (``ceil(max_seq/page_size)`` pages) so any
    request the engine accepts can always eventually be admitted."""
    budget = pages_for(int(frac * n_slots * max_seq), page_size)
    return max(pages_for(max_seq, page_size), budget)


class PoolExhausted(RuntimeError):
    """Raised on an allocation the reservation discipline should have
    made impossible (internal invariant violation)."""


class BlockAllocator:
    """Free-list allocator over ``n_pages`` KV pages (ids 1..n_pages;
    id 0 is the scratch page and is never handed out)."""

    def __init__(self, n_pages: int):
        if n_pages < 1:
            raise ValueError(f"pool needs >= 1 page, got {n_pages}")
        self.n_pages = n_pages
        self._free: Deque[int] = collections.deque(range(1, n_pages + 1))
        self._free_set = set(self._free)   # double-release detection
        self.reserved = 0                  # promised but not yet allocated
        self.in_use = 0
        self.peak_in_use = 0
        self.peak_committed = 0            # in_use + outstanding reservation

    # -- reservation (admission control) -----------------------------------
    def available(self) -> int:
        """Pages free AND not spoken for by an existing reservation."""
        return len(self._free) - self.reserved

    def can_reserve(self, n: int) -> bool:
        return self.available() >= n

    def reserve(self, n: int) -> None:
        if not self.can_reserve(n):
            raise PoolExhausted(
                f"cannot reserve {n} pages ({self.available()} available)")
        self.reserved += n
        self.peak_committed = max(self.peak_committed,
                                  self.in_use + self.reserved)

    # -- allocation (always against a prior reservation) -------------------
    def alloc(self, n: int = 1) -> List[int]:
        if n > self.reserved or n > len(self._free):
            raise PoolExhausted(
                f"alloc({n}) exceeds reservation {self.reserved} / "
                f"free {len(self._free)}")
        ids = [self._free.popleft() for _ in range(n)]
        self._free_set.difference_update(ids)
        self.reserved -= n
        self.in_use += n
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return ids

    def release(self, ids: List[int], unreserve: int = 0) -> None:
        """Return ``ids`` to the free list and drop ``unreserve`` pages
        of never-allocated reservation (eviction before max_new)."""
        for i in ids:
            if not 1 <= i <= self.n_pages or i in self._free_set:
                # a double-released page would later be handed to two
                # live sequences — silent KV corruption, so fail loudly
                raise PoolExhausted(f"release of invalid/free page {i}")
        self._free.extend(ids)
        self._free_set.update(ids)
        self.in_use -= len(ids)
        self.reserved -= unreserve
        assert self.in_use >= 0 and self.reserved >= 0

    # -- stats --------------------------------------------------------------
    def utilization(self) -> float:
        """Peak fraction of the pool ever holding live KV."""
        return self.peak_in_use / self.n_pages


# ==========================================================================
# KV-delta spill store
# ==========================================================================

@dataclass
class SpillRecord:
    """Host-side spill state of one sequence across preemption epochs."""
    kv: object                  # prefix-shaped pytree, leaves (L,1,n*ps,...)
    #                             — or its packed form under a codec:
    #                             (treedef, [(blob, dtype_str, shape), ...])
    synced_pages: int           # pages of ``kv`` merged so far
    epoch: int = 0              # spills merged into this record
    nbytes: int = 0             # bytes this record holds on the host
    #                             (compressed bytes under a codec)


class DeltaSpillStore:
    """Host store for spilled KV with per-sequence delta merging.

    A sequence's first spill ships its whole live page set; every later
    spill ships only the pages dirtied since (the engine's block tables
    track a ``synced_pages`` watermark — pages [0, synced) are
    bit-identical to this store's copy).  ``merge`` reassembles
    base + delta into the full prefix-shaped snapshot a resume grafts
    back, token-exactly, and accounts actual-vs-full spill bytes so the
    benchmark can gate that the delta format really ships less.

    Records persist across resumes (that is what makes the NEXT spill a
    delta) and are dropped when the sequence finishes.

    ``codec="zstd"`` (optional ``zstandard`` dep, same policy as
    ``repro.checkpoint.store``) keeps host entries compressed —
    lossless, so merges stay bit-exact — and meters the compressed
    delta bytes alongside the raw byte ledger.

    ``max_entries`` / ``max_bytes`` bound the store: inserting past
    either cap evicts least-recently-SPILLED records (never the one
    just written).  Evicted rids are surfaced through ``take_evicted``
    so the scheduler can redo long-idle swapped sequences from prefill
    instead of resuming from a snapshot that no longer exists.
    """

    def __init__(self, page_size: int, *, codec: Optional[str] = None,
                 max_entries: Optional[int] = None,
                 max_bytes: Optional[int] = None):
        if codec not in (None, "zstd"):
            raise ValueError(f"unknown spill codec {codec!r}")
        if codec == "zstd" and zstd is None:
            raise RuntimeError(
                "spill codec 'zstd' requested but the 'zstandard' package "
                "is not installed — install it or pass codec=None")
        self.page_size = page_size
        self.codec = codec
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self._by_rid: Dict[int, SpillRecord] = {}   # insertion-ordered: LRU
        self._evicted: List[int] = []
        self.stored_bytes = 0       # live host bytes (compressed if codec)
        self.n_evictions = 0
        self.n_spills = 0
        self.n_delta_spills = 0     # spills that shipped < the live set
        self.bytes_spilled = 0      # actually shipped (delta) bytes
        self.bytes_compressed = 0   # same deltas after the codec (0 w/o)
        self.bytes_full_equiv = 0   # what full spills would have shipped

    def __contains__(self, rid: int) -> bool:
        return rid in self._by_rid

    def __len__(self) -> int:
        return len(self._by_rid)

    def record(self, rid: int) -> Optional[SpillRecord]:
        return self._by_rid.get(rid)

    def snapshot(self, rid: int):
        """The full prefix-shaped KV snapshot of ``rid``'s record
        (decompressed under a codec) — what a resume grafts back.  The
        record is the ONLY host copy of a store-managed spill."""
        return self._unpack(self._by_rid[rid].kv)

    def synced_pages(self, rid: int) -> int:
        rec = self._by_rid.get(rid)
        return rec.synced_pages if rec is not None else 0

    @staticmethod
    def _nbytes(tree) -> int:
        return int(sum(np.asarray(l).nbytes for l in jax.tree.leaves(tree)))

    # -- codec --------------------------------------------------------------
    def _pack(self, tree):
        """(packed_kv, host_bytes) — identity without a codec."""
        if self.codec is None:
            return tree, self._nbytes(tree)
        leaves, treedef = jax.tree.flatten(tree)
        cctx = zstd.ZstdCompressor(level=3)
        packed = []
        for l in leaves:
            a = np.ascontiguousarray(np.asarray(l))
            packed.append((cctx.compress(a.tobytes()), a.dtype.str, a.shape))
        return (treedef, packed), sum(len(b) for b, _, _ in packed)

    def _unpack(self, kv):
        if self.codec is None:
            return kv
        treedef, packed = kv
        dctx = zstd.ZstdDecompressor()
        leaves = [np.frombuffer(dctx.decompress(b),
                                dtype=np.dtype(dt)).reshape(shape)
                  for b, dt, shape in packed]
        return jax.tree.unflatten(treedef, leaves)

    # -- LRU eviction --------------------------------------------------------
    def _evict_over_caps(self, keep: int) -> None:
        def over() -> bool:
            return ((self.max_entries is not None
                     and len(self._by_rid) > self.max_entries)
                    or (self.max_bytes is not None
                        and self.stored_bytes > self.max_bytes))
        # dict order is insertion order and merge() re-inserts, so the
        # head is always the least-recently-spilled record
        while over() and len(self._by_rid) > 1:
            rid = next(iter(self._by_rid))
            if rid == keep:
                break                  # never evict the record just written
            rec = self._by_rid.pop(rid)
            self.stored_bytes -= rec.nbytes
            self.n_evictions += 1
            self._evicted.append(rid)

    def take_evicted(self) -> List[int]:
        """Evicted rids since the last call (the scheduler's redo hook)."""
        out, self._evicted = self._evicted, []
        return out

    def merge(self, rid: int, delta, synced: int, total_pages: int):
        """Merge ``delta`` (pages [synced, total_pages) of the live block
        table, prefix-shaped, or None when nothing was dirtied) into the
        sequence's record and return the full reassembled snapshot."""
        ps = self.page_size
        rec = self._by_rid.get(rid)
        base = self._unpack(rec.kv) if rec is not None else None
        if rec is None or synced == 0:
            assert delta is not None and synced == 0, (rid, synced)
            merged = delta
        elif delta is None:                      # re-spill with no new pages
            assert synced == total_pages, (synced, total_pages)
            merged = base
        else:
            merged = jax.tree.map(
                lambda b, d: np.concatenate(
                    [np.asarray(b)[:, :, :synced * ps], np.asarray(d)],
                    axis=2),
                base, delta)
        delta_bytes = self._nbytes(delta) if delta is not None else 0
        full_bytes = self._nbytes(merged)
        self.n_spills += 1
        self.n_delta_spills += int(delta_bytes < full_bytes)
        self.bytes_spilled += delta_bytes
        self.bytes_full_equiv += full_bytes
        if rec is not None:
            self.stored_bytes -= rec.nbytes
            del self._by_rid[rid]                # re-insert at the MRU end
        kv, nbytes = self._pack(merged)
        if self.codec is not None and delta is not None:
            # meter what the codec shipped: a first spill's merged IS
            # its delta (reuse the pack); a re-spill packs its (much
            # smaller) delta once more just for the ledger
            self.bytes_compressed += (nbytes if merged is delta
                                      else self._pack(delta)[1])
        self._by_rid[rid] = SpillRecord(kv=kv, synced_pages=total_pages,
                                        epoch=(rec.epoch + 1) if rec else 1,
                                        nbytes=nbytes)
        self.stored_bytes += nbytes
        self._evict_over_caps(keep=rid)
        return merged

    def drop(self, rid: int) -> None:
        rec = self._by_rid.pop(rid, None)
        if rec is not None:
            self.stored_bytes -= rec.nbytes

    def stats(self) -> dict:
        return {
            "n_delta_spills": self.n_delta_spills,
            "spill_bytes": self.bytes_spilled,
            "spill_bytes_full_equiv": self.bytes_full_equiv,
            "spill_bytes_compressed": self.bytes_compressed,
            "n_store_evictions": self.n_evictions,
            "spill_store_entries": len(self._by_rid),
            "spill_store_bytes": self.stored_bytes,
        }
