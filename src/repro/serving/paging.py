"""Paged KV-cache bookkeeping for the continuous engine.

A ``BlockAllocator`` owns a global pool of fixed-size KV pages.  Each
active sequence holds a growable block table (list of page ids); pages
are handed out on admission (prompt pages) and during decode (one page
every ``page_size`` generated tokens) and returned to the free list on
eviction.  Memory therefore scales with ``sum_i ceil(len_i/page_size)``
instead of ``n_slots * max_seq``.

Admission uses a *reservation* discipline so decode can never stall on
an empty pool: a request is only admitted when its worst-case lifetime
page count (``ceil((prompt + max_new - 1)/page_size)``) can be reserved
up front.  Pages are still allocated lazily against that reservation,
and any unused reservation is released on eviction.

Page id 0 is a scratch page: inactive slots (and unused block-table
entries) point at it, so their dummy decode writes land somewhere no
live sequence ever reads.  The allocator hands out ids ``1..n_pages``.
"""
from __future__ import annotations

import collections
from typing import Deque, List

SCRATCH_PAGE = 0


def pages_for(n_positions: int, page_size: int) -> int:
    """Number of pages covering ``n_positions`` cache positions."""
    return max(0, -(-n_positions // page_size))


def default_pool_pages(n_slots: int, max_seq: int, page_size: int,
                       frac: float = 0.75) -> int:
    """Default pool sizing: ``frac`` of the contiguous layout's
    ``n_slots * max_seq`` positions, but never smaller than one
    worst-case request (``ceil(max_seq/page_size)`` pages) so any
    request the engine accepts can always eventually be admitted."""
    budget = pages_for(int(frac * n_slots * max_seq), page_size)
    return max(pages_for(max_seq, page_size), budget)


class PoolExhausted(RuntimeError):
    """Raised on an allocation the reservation discipline should have
    made impossible (internal invariant violation)."""


class BlockAllocator:
    """Free-list allocator over ``n_pages`` KV pages (ids 1..n_pages;
    id 0 is the scratch page and is never handed out)."""

    def __init__(self, n_pages: int):
        if n_pages < 1:
            raise ValueError(f"pool needs >= 1 page, got {n_pages}")
        self.n_pages = n_pages
        self._free: Deque[int] = collections.deque(range(1, n_pages + 1))
        self._free_set = set(self._free)   # double-release detection
        self.reserved = 0                  # promised but not yet allocated
        self.in_use = 0
        self.peak_in_use = 0
        self.peak_committed = 0            # in_use + outstanding reservation

    # -- reservation (admission control) -----------------------------------
    def available(self) -> int:
        """Pages free AND not spoken for by an existing reservation."""
        return len(self._free) - self.reserved

    def can_reserve(self, n: int) -> bool:
        return self.available() >= n

    def reserve(self, n: int) -> None:
        if not self.can_reserve(n):
            raise PoolExhausted(
                f"cannot reserve {n} pages ({self.available()} available)")
        self.reserved += n
        self.peak_committed = max(self.peak_committed,
                                  self.in_use + self.reserved)

    # -- allocation (always against a prior reservation) -------------------
    def alloc(self, n: int = 1) -> List[int]:
        if n > self.reserved or n > len(self._free):
            raise PoolExhausted(
                f"alloc({n}) exceeds reservation {self.reserved} / "
                f"free {len(self._free)}")
        ids = [self._free.popleft() for _ in range(n)]
        self._free_set.difference_update(ids)
        self.reserved -= n
        self.in_use += n
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return ids

    def release(self, ids: List[int], unreserve: int = 0) -> None:
        """Return ``ids`` to the free list and drop ``unreserve`` pages
        of never-allocated reservation (eviction before max_new)."""
        for i in ids:
            if not 1 <= i <= self.n_pages or i in self._free_set:
                # a double-released page would later be handed to two
                # live sequences — silent KV corruption, so fail loudly
                raise PoolExhausted(f"release of invalid/free page {i}")
        self._free.extend(ids)
        self._free_set.update(ids)
        self.in_use -= len(ids)
        self.reserved -= unreserve
        assert self.in_use >= 0 and self.reserved >= 0

    # -- stats --------------------------------------------------------------
    def utilization(self) -> float:
        """Peak fraction of the pool ever holding live KV."""
        return self.peak_in_use / self.n_pages
