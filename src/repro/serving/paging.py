"""Paged KV-cache bookkeeping for the continuous engine.

A ``BlockAllocator`` owns a global pool of fixed-size KV pages.  Each
active sequence holds a growable block table (list of page ids); pages
are handed out on admission (prompt pages) and during decode (one page
every ``page_size`` generated tokens) and returned to the free list on
eviction.  Memory therefore scales with ``sum_i ceil(len_i/page_size)``
instead of ``n_slots * max_seq``.

Pages are refcounted so immutable prompt pages can be SHARED: a
``PagePrefixIndex`` (radix trie keyed on page-granular token runs)
maps full prompt pages to page ids, letting sequences with a common
prefix attach cache-hit pages by reference instead of recomputing
them; the first write into a shared page forks a private copy
(copy-on-write, in ``serving.engine.PagedSlotManager``).

Admission uses a *reservation* discipline so decode can never stall on
an empty pool: a request is only admitted when its worst-case lifetime
page count (``ceil((prompt + max_new - 1)/page_size)``) can be reserved
up front.  Pages are still allocated lazily against that reservation,
and any unused reservation is released on eviction.

Page id 0 is a scratch page: inactive slots (and unused block-table
entries) point at it, so their dummy decode writes land somewhere no
live sequence ever reads.  The allocator hands out ids ``1..n_pages``.
"""
from __future__ import annotations

import collections
import zlib
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional

import jax
import numpy as np

try:                                   # optional dep, same policy as
    import zstandard as zstd           # repro.checkpoint.store
except ImportError:                    # pragma: no cover
    zstd = None

SCRATCH_PAGE = 0


def pages_for(n_positions: int, page_size: int) -> int:
    """Number of pages covering ``n_positions`` cache positions."""
    return max(0, -(-n_positions // page_size))


def default_pool_pages(n_slots: int, max_seq: int, page_size: int,
                       frac: float = 0.75) -> int:
    """Default pool sizing: ``frac`` of the contiguous layout's
    ``n_slots * max_seq`` positions, but never smaller than one
    worst-case request (``ceil(max_seq/page_size)`` pages) so any
    request the engine accepts can always eventually be admitted."""
    budget = pages_for(int(frac * n_slots * max_seq), page_size)
    return max(pages_for(max_seq, page_size), budget)


class PoolExhausted(RuntimeError):
    """Raised on an allocation the reservation discipline should have
    made impossible (internal invariant violation)."""


class SpillCorruption(RuntimeError):
    """A spill record failed its checksum — the host copy cannot be
    trusted and must never be grafted back into paged KV.  The caller
    redoes the sequence from prefill instead."""


class BlockAllocator:
    """Free-list allocator over ``n_pages`` KV pages (ids 1..n_pages;
    id 0 is the scratch page and is never handed out).

    Pages are REFCOUNTED: ``alloc`` hands a page out with one
    reference, ``share`` adds holders (prefix sharing — several block
    tables pointing at the same immutable prompt page), and ``release``
    drops one reference per listed id.  A page returns to the free list
    only when its refcount reaches zero, so ``in_use`` counts DISTINCT
    live pages (``len(_free) == n_pages - in_use`` always holds) while
    shared pages cost the pool — and the reservation ledger — only
    once."""

    def __init__(self, n_pages: int):
        if n_pages < 1:
            raise ValueError(f"pool needs >= 1 page, got {n_pages}")
        self.n_pages = n_pages
        self._free: Deque[int] = collections.deque(range(1, n_pages + 1))
        self._free_set = set(self._free)   # double-release detection
        self._refcount: Dict[int, int] = {}   # live page -> holders
        self.reserved = 0                  # promised but not yet allocated
        self.in_use = 0
        self.peak_in_use = 0
        self.peak_committed = 0            # in_use + outstanding reservation

    # -- reservation (admission control) -----------------------------------
    def available(self) -> int:
        """Pages free AND not spoken for by an existing reservation."""
        return len(self._free) - self.reserved

    def can_reserve(self, n: int) -> bool:
        return self.available() >= n

    def reserve(self, n: int) -> None:
        if not self.can_reserve(n):
            raise PoolExhausted(
                f"cannot reserve {n} pages ({self.available()} available)")
        self.reserved += n
        self.peak_committed = max(self.peak_committed,
                                  self.in_use + self.reserved)

    # -- allocation (always against a prior reservation) -------------------
    def alloc(self, n: int = 1) -> List[int]:
        if n > self.reserved or n > len(self._free):
            raise PoolExhausted(
                f"alloc({n}) exceeds reservation {self.reserved} / "
                f"free {len(self._free)}")
        ids = [self._free.popleft() for _ in range(n)]
        self._free_set.difference_update(ids)
        for i in ids:
            self._refcount[i] = 1
        self.reserved -= n
        self.in_use += n
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return ids

    # -- sharing (prefix cache) ---------------------------------------------
    def share(self, ids: List[int]) -> None:
        """Add one holder to each live page in ``ids`` (a block table —
        or the prefix index — attaching cached pages by reference).
        Consumes no reservation: the pages are already in use, and the
        new holder's ``release`` merely drops its reference."""
        for i in ids:
            if not 1 <= i <= self.n_pages or i in self._free_set:
                raise PoolExhausted(f"share of invalid/free page {i}")
        for i in ids:
            self._refcount[i] += 1

    def refcount(self, i: int) -> int:
        """Current holders of page ``i`` (0 when free)."""
        return self._refcount.get(i, 0)

    def n_live_refs(self) -> int:
        """Total outstanding references across all live pages — 0 iff
        every holder released everything (the drain gate)."""
        return sum(self._refcount.values())

    def release(self, ids: List[int], unreserve: int = 0) -> None:
        """Drop one reference per page in ``ids``; pages reaching
        refcount zero return to the free list.  ``unreserve`` drops that
        many pages of never-allocated reservation (eviction before
        max_new)."""
        freed = []
        for i in ids:
            if not 1 <= i <= self.n_pages or i in self._free_set:
                # a double-released page would later be handed to two
                # live sequences — silent KV corruption, so fail loudly
                raise PoolExhausted(f"release of invalid/free page {i}")
            rc = self._refcount[i] - 1
            if rc:
                self._refcount[i] = rc
            else:
                del self._refcount[i]
                freed.append(i)
                self._free.append(i)
                self._free_set.add(i)
        self.in_use -= len(freed)
        self.reserved -= unreserve
        if self.in_use < 0 or self.reserved < 0:
            raise PoolExhausted(
                f"accounting went negative (in_use={self.in_use}, "
                f"reserved={self.reserved}) — over-release or bad unreserve")

    # -- stats --------------------------------------------------------------
    def utilization(self) -> float:
        """Peak fraction of the pool ever holding live KV."""
        return self.peak_in_use / self.n_pages


def per_device_pool_stats(allocator: BlockAllocator, *, n_shards: int,
                          kv_bytes_per_device: int) -> dict:
    """Per-device ledger view of a head-sharded paged pool.

    The mesh cuts only the KV-head (or MLA latent-rank) axis of the pool
    leaves — never the layer/page/offset axes — so every device holds
    the SAME page ids and the global :class:`BlockAllocator` ledger is
    replicated device-for-device: per-device page counts EQUAL the
    global counts while bytes scale down by the head shard.  The
    invariant ``kv_bytes_per_device * n_shards >= global bytes`` holds
    with equality when every leaf's sharded dim divides the mesh axis
    (replicated-fallback leaves push the product above the global)."""
    return {
        "n_kv_shards": n_shards,
        "kv_bytes_per_device": kv_bytes_per_device,
        "pages_in_use_per_device": allocator.in_use,
        "peak_pages_in_use_per_device": allocator.peak_in_use,
    }


# ==========================================================================
# prefix sharing: radix index over full prompt pages
# ==========================================================================

class PagePrefixIndex:
    """Radix (trie) index mapping FULL prompt pages to pooled page ids.

    Level ``d`` of the trie is keyed by the tuple of token ids filling
    prompt page ``d``, so a lookup walks a prompt page-by-page and
    returns the longest run of leading pages whose KV is already
    resident in the pool.  Only IMMUTABLE pages are ever indexed —
    pages fully covered by a prompt (decode never writes into them),
    registered when their sequence finishes prefill.

    The index holds ONE allocator reference per indexed page (via
    ``BlockAllocator.share``), so cached pages survive the sequences
    that produced them; each attaching sequence adds its own reference
    and a page only frees once the index AND every sequence released
    it.  ``reclaimable``/``evict`` let admission reclaim index-only
    pages (refcount 1) leaf-first when the pool runs dry — evicting a
    leaf can cascade to its (now-leaf) ancestors, never the other way,
    so the trie's prefix property is preserved.  ``clear`` drops every
    index reference (the benchmark's refcount-drain gate)."""

    def __init__(self, allocator: BlockAllocator, page_size: int):
        self.allocator = allocator
        self.page_size = page_size
        # node: key (page-token tuple) -> [page_id, children, lru_stamp]
        self._root: Dict[tuple, list] = {}
        self._clock = 0
        self.n_pages = 0            # pages currently holding an index ref
        self.hits = 0               # admissions that attached >= 1 page
        self.misses = 0
        self.pages_attached = 0     # pages attached by reference, total
        self.pages_evicted = 0

    def _keys(self, tokens) -> List[tuple]:
        ps = self.page_size
        return [tuple(int(t) for t in tokens[d * ps:(d + 1) * ps])
                for d in range(len(tokens) // ps)]

    def match(self, tokens) -> List[int]:
        """Page ids of the longest indexed run of ``tokens``'s leading
        full pages.  Read-only: takes no references — the caller
        attaches via ``BlockAllocator.share``."""
        self._clock += 1
        node, out = self._root, []
        for key in self._keys(tokens):
            ent = node.get(key)
            if ent is None:
                break
            ent[2] = self._clock
            out.append(ent[0])
            node = ent[1]
        return out

    def note_attach(self, n_pages: int) -> None:
        """Hit/miss accounting for one admission lookup."""
        if n_pages:
            self.hits += 1
            self.pages_attached += n_pages
        else:
            self.misses += 1

    def insert(self, tokens, pages: List[int]) -> int:
        """Index the leading full pages of ``tokens`` (their KV living
        in ``pages``).  Already-indexed prefixes keep their existing
        page (first writer wins — both copies are bit-identical, built
        from the same token prefix).  Takes one index reference per
        NEWLY indexed page; returns how many were new."""
        self._clock += 1
        node, added = self._root, 0
        for d, key in enumerate(self._keys(tokens)):
            ent = node.get(key)
            if ent is None:
                self.allocator.share([pages[d]])
                ent = node[key] = [pages[d], {}, self._clock]
                self.n_pages += 1
                added += 1
            else:
                ent[2] = self._clock
            node = ent[1]
        return added

    def reclaimable(self) -> int:
        """Pages a cascade of leaf evictions could free right now:
        index-only pages (refcount 1) whose whole subtree is likewise
        evictable."""
        def count(node) -> tuple:
            n, full = 0, True
            for ent in node.values():
                sub_n, sub_full = count(ent[1])
                n += sub_n
                ok = sub_full and self.allocator.refcount(ent[0]) == 1
                n += int(ok)
                full = full and ok
            return n, full
        return count(self._root)[0]

    def _evictable_leaves(self) -> List[tuple]:
        """(lru_stamp, page_id, key, parent) for every leaf node whose
        page only the index still references."""
        out, stack = [], [self._root]
        while stack:
            node = stack.pop()
            for key, ent in node.items():
                if ent[1]:
                    stack.append(ent[1])
                elif self.allocator.refcount(ent[0]) == 1:
                    out.append((ent[2], ent[0], key, node))
        return out

    def evict(self, n: int) -> int:
        """Free up to ``n`` index-only pages, least-recently-used leaf
        first (an emptied parent becomes evictable next round); returns
        how many were actually freed."""
        freed = 0
        while freed < n:
            cands = sorted(self._evictable_leaves(), key=lambda c: c[:2])
            if not cands:
                break
            for _, page, key, parent in cands[:n - freed]:
                del parent[key]
                self.allocator.release([page])
                self.n_pages -= 1
                self.pages_evicted += 1
                freed += 1
        return freed

    def clear(self) -> None:
        """Drop every index reference (end-of-run drain)."""
        def drop(node):
            for ent in node.values():
                drop(ent[1])
                self.allocator.release([ent[0]])
            node.clear()
        drop(self._root)
        self.n_pages = 0

    def stats(self) -> dict:
        return {
            "prefix_index_pages": self.n_pages,
            "prefix_hits": self.hits,
            "prefix_misses": self.misses,
            "prefix_pages_attached": self.pages_attached,
            "prefix_pages_evicted": self.pages_evicted,
        }


# ==========================================================================
# KV-delta spill store
# ==========================================================================

@dataclass
class SpillRecord:
    """Host-side spill state of one sequence across preemption epochs."""
    kv: object                  # prefix-shaped pytree, leaves (L,1,n*ps,...)
    #                             — or its packed form under a codec:
    #                             (treedef, [(blob, dtype_str, shape), ...])
    synced_pages: int           # pages of ``kv`` merged so far
    epoch: int = 0              # spills merged into this record
    nbytes: int = 0             # bytes this record holds on the host
    #                             (compressed bytes under a codec)
    crc: int = 0                # CRC32 over the packed record bytes,
    #                             computed at merge, verified at every read


class DeltaSpillStore:
    """Host store for spilled KV with per-sequence delta merging.

    A sequence's first spill ships its whole live page set; every later
    spill ships only the pages dirtied since (the engine's block tables
    track a ``synced_pages`` watermark — pages [0, synced) are
    bit-identical to this store's copy).  ``merge`` reassembles
    base + delta into the full prefix-shaped snapshot a resume grafts
    back, token-exactly, and accounts actual-vs-full spill bytes so the
    benchmark can gate that the delta format really ships less.

    Records persist across resumes (that is what makes the NEXT spill a
    delta) and are dropped when the sequence finishes.

    ``codec="zstd"`` (optional ``zstandard`` dep, same policy as
    ``repro.checkpoint.store``) keeps host entries compressed —
    lossless, so merges stay bit-exact — and meters the compressed
    delta bytes alongside the raw byte ledger.

    ``max_entries`` / ``max_bytes`` bound the store: inserting past
    either cap evicts least-recently-SPILLED records (never the one
    just written).  Evicted rids are surfaced through ``take_evicted``
    so the scheduler can redo long-idle swapped sequences from prefill
    instead of resuming from a snapshot that no longer exists.

    INTEGRITY: every record carries a CRC32 over its packed host bytes
    (the compressed blobs under a codec), computed at ``merge`` and
    verified on every read — ``snapshot`` (resume/checkpoint), the base
    reuse inside ``merge``, and the exit audits in ``drop`` and LRU
    eviction.  A mismatch discards the record, increments
    ``n_corruptions_detected`` and (on the read paths) raises
    :class:`SpillCorruption`; a corrupted snapshot is NEVER returned,
    so a bit flip in host memory costs a redo-from-prefill, not a
    silent garbage graft.  An optional
    :class:`repro.core.faults.FaultInjector` flips a byte in every
    k-th merged record to prove the detection path end to end.
    """

    def __init__(self, page_size: int, *, codec: Optional[str] = None,
                 max_entries: Optional[int] = None,
                 max_bytes: Optional[int] = None,
                 injector=None):
        if codec not in (None, "zstd"):
            raise ValueError(f"unknown spill codec {codec!r}")
        if codec == "zstd" and zstd is None:
            raise RuntimeError(
                "spill codec 'zstd' requested but the 'zstandard' package "
                "is not installed — install it or pass codec=None")
        self.page_size = page_size
        self.codec = codec
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self.injector = injector
        self._by_rid: Dict[int, SpillRecord] = {}   # insertion-ordered: LRU
        self._evicted: List[int] = []
        self.stored_bytes = 0       # live host bytes (compressed if codec)
        self.n_corruptions_detected = 0
        self.n_evictions = 0
        self.n_spills = 0
        self.n_delta_spills = 0     # spills that shipped < the live set
        self.bytes_spilled = 0      # actually shipped (delta) bytes
        self.bytes_compressed = 0   # same deltas after the codec (0 w/o)
        self.bytes_full_equiv = 0   # what full spills would have shipped

    def __contains__(self, rid: int) -> bool:
        return rid in self._by_rid

    def __len__(self) -> int:
        return len(self._by_rid)

    def record(self, rid: int) -> Optional[SpillRecord]:
        return self._by_rid.get(rid)

    def snapshot(self, rid: int):
        """The full prefix-shaped KV snapshot of ``rid``'s record
        (decompressed under a codec) — what a resume grafts back.  The
        record is the ONLY host copy of a store-managed spill.  Raises
        :class:`SpillCorruption` (and discards the record) if the bytes
        no longer match their merge-time checksum."""
        rec = self._by_rid[rid]
        if self._crc(rec.kv) != rec.crc:
            self._discard_corrupt(rid)
            raise SpillCorruption(
                f"spill record for rid {rid} failed its checksum at "
                f"snapshot (epoch {rec.epoch})")
        return self._unpack(rec.kv)

    def synced_pages(self, rid: int) -> int:
        rec = self._by_rid.get(rid)
        return rec.synced_pages if rec is not None else 0

    @staticmethod
    def _nbytes(tree) -> int:
        return int(sum(np.asarray(l).nbytes for l in jax.tree.leaves(tree)))

    # -- integrity -----------------------------------------------------------
    def _crc(self, kv) -> int:
        """CRC32 over the packed record bytes — array contents without a
        codec, the compressed blobs with one (verified BEFORE any
        decompression touches the data)."""
        c = 0
        if self.codec is None:
            for l in jax.tree.leaves(kv):
                a = np.ascontiguousarray(np.asarray(l))
                c = zlib.crc32(a.tobytes(), c)
        else:
            for blob, _, _ in kv[1]:
                c = zlib.crc32(blob, c)
        return c

    def _discard_corrupt(self, rid: int) -> None:
        rec = self._by_rid.pop(rid)
        self.stored_bytes -= rec.nbytes
        self.n_corruptions_detected += 1

    def _maybe_inject(self, rid: int) -> None:
        """Fault hook: flip one byte of the freshly merged record (in a
        COPY — ``merge``'s return value aliases caller arrays) without
        touching its stored checksum, modeling at-rest host corruption
        the next read must catch."""
        if self.injector is None or not self.injector.spill_corruption_due():
            return
        rec = self._by_rid[rid]
        if self.codec is None:
            leaves, treedef = jax.tree.flatten(rec.kv)
            i = next(j for j, l in enumerate(leaves)
                     if np.asarray(l).nbytes > 0)
            a = np.array(np.asarray(leaves[i]), copy=True)
            raw = a.view(np.uint8).reshape(-1)
            raw[self.injector.corrupt_offset(a.nbytes)] ^= 0x01
            leaves[i] = a
            rec.kv = jax.tree.unflatten(treedef, leaves)
        else:
            treedef, packed = rec.kv
            blob, dt, shape = packed[0]
            buf = bytearray(blob)
            buf[self.injector.corrupt_offset(len(buf))] ^= 0x01
            rec.kv = (treedef, [(bytes(buf), dt, shape)] + packed[1:])

    # -- codec --------------------------------------------------------------
    def _pack(self, tree):
        """(packed_kv, host_bytes) — identity without a codec."""
        if self.codec is None:
            return tree, self._nbytes(tree)
        leaves, treedef = jax.tree.flatten(tree)
        cctx = zstd.ZstdCompressor(level=3)
        packed = []
        for l in leaves:
            a = np.ascontiguousarray(np.asarray(l))
            packed.append((cctx.compress(a.tobytes()), a.dtype.str, a.shape))
        return (treedef, packed), sum(len(b) for b, _, _ in packed)

    def _unpack(self, kv):
        if self.codec is None:
            return kv
        treedef, packed = kv
        dctx = zstd.ZstdDecompressor()
        leaves = [np.frombuffer(dctx.decompress(b),
                                dtype=np.dtype(dt)).reshape(shape)
                  for b, dt, shape in packed]
        return jax.tree.unflatten(treedef, leaves)

    # -- LRU eviction --------------------------------------------------------
    def _evict_over_caps(self, keep: int) -> None:
        def over() -> bool:
            return ((self.max_entries is not None
                     and len(self._by_rid) > self.max_entries)
                    or (self.max_bytes is not None
                        and self.stored_bytes > self.max_bytes))
        # dict order is insertion order and merge() re-inserts, so the
        # head is always the least-recently-spilled record
        while over() and len(self._by_rid) > 1:
            rid = next(iter(self._by_rid))
            if rid == keep:
                break                  # never evict the record just written
            rec = self._by_rid.pop(rid)
            self.stored_bytes -= rec.nbytes
            self.n_evictions += 1
            if self._crc(rec.kv) != rec.crc:
                # exit audit: the corruption never grafted (eviction
                # already routes through redo-from-prefill), but it must
                # still be COUNTED or detection coverage lies
                self.n_corruptions_detected += 1
            self._evicted.append(rid)

    def take_evicted(self) -> List[int]:
        """Evicted rids since the last call (the scheduler's redo hook)."""
        out, self._evicted = self._evicted, []
        return out

    def merge(self, rid: int, delta, synced: int, total_pages: int):
        """Merge ``delta`` (pages [synced, total_pages) of the live block
        table, prefix-shaped, or None when nothing was dirtied) into the
        sequence's record and return the full reassembled snapshot."""
        ps = self.page_size
        rec = self._by_rid.get(rid)
        if rec is not None and self._crc(rec.kv) != rec.crc:
            self._discard_corrupt(rid)
            raise SpillCorruption(
                f"spill record for rid {rid} failed its checksum at merge "
                f"(epoch {rec.epoch}) — base unusable, re-spill full")
        base = self._unpack(rec.kv) if rec is not None else None
        if rec is None or synced == 0:
            if delta is None or synced != 0:
                raise RuntimeError(
                    f"spill of rid {rid}: no base record yet its delta "
                    f"starts at page {synced} — stale synced watermark")
            merged = delta
        elif delta is None:                      # re-spill with no new pages
            if synced != total_pages:
                raise RuntimeError(
                    f"spill of rid {rid}: empty delta but only {synced} of "
                    f"{total_pages} pages are synced")
            merged = base
        else:
            merged = jax.tree.map(
                lambda b, d: np.concatenate(
                    [np.asarray(b)[:, :, :synced * ps], np.asarray(d)],
                    axis=2),
                base, delta)
        delta_bytes = self._nbytes(delta) if delta is not None else 0
        full_bytes = self._nbytes(merged)
        self.n_spills += 1
        self.n_delta_spills += int(delta_bytes < full_bytes)
        self.bytes_spilled += delta_bytes
        self.bytes_full_equiv += full_bytes
        if rec is not None:
            self.stored_bytes -= rec.nbytes
            del self._by_rid[rid]                # re-insert at the MRU end
        kv, nbytes = self._pack(merged)
        if self.codec is not None and delta is not None:
            # meter what the codec shipped: a first spill's merged IS
            # its delta (reuse the pack); a re-spill packs its (much
            # smaller) delta once more just for the ledger
            self.bytes_compressed += (nbytes if merged is delta
                                      else self._pack(delta)[1])
        self._by_rid[rid] = SpillRecord(kv=kv, synced_pages=total_pages,
                                        epoch=(rec.epoch + 1) if rec else 1,
                                        nbytes=nbytes, crc=self._crc(kv))
        self.stored_bytes += nbytes
        self._evict_over_caps(keep=rid)
        self._maybe_inject(rid)
        return merged

    def drop(self, rid: int) -> None:
        rec = self._by_rid.pop(rid, None)
        if rec is not None:
            self.stored_bytes -= rec.nbytes
            if self._crc(rec.kv) != rec.crc:
                # exit audit on the finished-sequence path: never read,
                # never grafted, but counted (see _evict_over_caps)
                self.n_corruptions_detected += 1

    @staticmethod
    def empty_stats() -> dict:
        """The all-zero stats schema.  ``stats()`` fills exactly these
        keys, and the scheduler's no-store path returns this directly —
        ONE schema, so a new stat key can never drift between the two
        (it used to be a hand-duplicated dict that only broke on the
        no-store path)."""
        return {
            "n_delta_spills": 0,
            "spill_bytes": 0,
            "spill_bytes_full_equiv": 0,
            "spill_bytes_compressed": 0,
            "n_store_evictions": 0,
            "n_spill_corruptions_detected": 0,
            "spill_store_entries": 0,
            "spill_store_bytes": 0,
        }

    def stats(self) -> dict:
        out = self.empty_stats()
        out.update(
            n_delta_spills=self.n_delta_spills,
            spill_bytes=self.bytes_spilled,
            spill_bytes_full_equiv=self.bytes_full_equiv,
            spill_bytes_compressed=self.bytes_compressed,
            n_store_evictions=self.n_evictions,
            n_spill_corruptions_detected=self.n_corruptions_detected,
            spill_store_entries=len(self._by_rid),
            spill_store_bytes=self.stored_bytes,
        )
        return out

    # -- checkpoint bookkeeping ---------------------------------------------
    # Records themselves re-materialize as swap-entry snapshots after a
    # restore; only the cumulative counters travel through a checkpoint
    # (so a crash-rollback keeps injected-vs-detected exact).
    _COUNTER_KEYS = ("n_evictions", "n_spills", "n_delta_spills",
                     "bytes_spilled", "bytes_compressed", "bytes_full_equiv",
                     "n_corruptions_detected")

    def counters(self) -> dict:
        return {k: getattr(self, k) for k in self._COUNTER_KEYS}

    def load_counters(self, d: dict) -> None:
        for k in self._COUNTER_KEYS:
            setattr(self, k, d[k])
