"""Constellation-scale serving: K satellites, M ground stations.

The paper's verification flew on the Tiansuan constellation, but
``serving.scheduler.SpaceGroundScheduler`` drives a single
onboard/ground pair on one periodic schedule.  The interesting systems
problems start when several cloud-native satellites contend for scarce
ground-station pass seconds (PAPERS.md: "Space-Based Computing
Networks"):

  * ``ContactPlanner`` — per tick, assigns each ground station to at
    most one satellite downlink lane (a station serves ONE lane per
    tick, and a satellite's single downlink radio serves one station).
    Assignment maximizes a *priority-to-value* objective per pass
    second: expected remaining tokens x the request priority weight
    (``1 + max(Request.priority, 0)`` — the default priority 0 still
    carries value) / pass cost, where a payload's "remaining tokens"
    are the tokens not yet on the ground and the pass cost is the ticks
    its backlog needs at the link rate.  ``policy="static"`` is the
    K-independent-pairs comparator: every satellite only ever talks to
    its home station (``sat % n_stations``), lowest index first on
    conflicts, no coordination.

  * ``ConstellationScheduler`` — drives K ``ContinuousEngine``s (one
    ``PreemptiveScheduler`` each) against per-(satellite, station)
    window sets (``ContactSchedule.step_window_sets``) on one shared
    tick clock, metering per-satellite energy/bytes through
    ``core.energy.FleetEnergy``.

  * **Inter-satellite handover** — when a sequence's owner loses its
    window (its next pass over ANY station starts later than a peer's
    by more than ``handover_margin_ticks``), the scheduler spills the
    sequence (the ``DeltaSpillStore`` record is the wire format — the
    same delta-merged, CRC-checksummed host snapshot every preemption
    produces), serializes it through ``checkpoint/store.py`` exactly as
    ``PreemptiveScheduler.checkpoint`` would, and ships the bytes over
    a framed ``TransmitLane`` (so faults and ARQ apply: corrupt frames
    are NACKed and retransmitted, an exhausted retry budget re-enqueues
    the payload).  The destination grafts it as a spilled swap entry —
    the ``restore`` path — and greedy decode continues **token-exactly**.
    A spill record that fails its checksum at serialization time takes
    the existing corruption->redo lane (``_redo_corrupt``: the source
    requeues the request from prefill; never a garbage graft).
    Finished-but-undelivered answers ride the same ISL as compact
    result payloads toward the satellite with the earliest pass.

Determinism: same traces + same window sets + same fault plan => same
tokens, handovers, assignments and ledgers.
"""
from __future__ import annotations

import os
import tempfile
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.store import load_checkpoint_raw, save_checkpoint
from repro.core.energy import EnergyModel, FleetEnergy
from repro.core.faults import FaultInjector
from repro.core.link import LinkModel, TransmitLane, payload_bytes_result
from repro.serving.batching import Request
from repro.serving.engine import ContinuousEngine, _PagedSlotState
from repro.serving.paging import SpillCorruption
from repro.serving.scheduler import PreemptiveScheduler, SwapEntry


def priority_weight(priority: int) -> float:
    """Positive value weight for the planner objective — priority 0
    (the default) must still carry value, so the weight floors at 1."""
    return 1.0 + max(int(priority), 0)


# ==========================================================================
# pass-second assignment
# ==========================================================================

@dataclass
class ContactPlanner:
    """Assigns ground-station pass seconds to satellite downlink lanes.

    ``window_sets`` maps (satellite, station) -> tick-quantized
    ``[(lo, hi))`` visibility windows.  Capacity discipline (the
    property suite gates these): per tick, at most one satellite per
    station and one station per satellite — assigned pass seconds per
    tick never exceed ``n_stations * s_per_step``.
    """
    window_sets: Dict[Tuple[int, int], List[Tuple[int, int]]]
    n_satellites: int
    n_stations: int
    policy: str = "value"            # "value" | "static" (home stations)

    def __post_init__(self):
        if self.policy not in ("value", "static"):
            raise ValueError(f"unknown planner policy {self.policy!r}")

    def in_window(self, sat: int, station: int, t: int) -> bool:
        return any(lo <= t < hi
                   for lo, hi in self.window_sets.get((sat, station), []))

    def open_pairs(self, t: int) -> List[Tuple[int, int]]:
        return [(k, m) for k in range(self.n_satellites)
                for m in range(self.n_stations) if self.in_window(k, m, t)]

    def next_open(self, sat: int, t: int) -> Optional[int]:
        """Earliest tick >= t at which ``sat`` sees ANY station (the
        handover trigger compares these across the fleet)."""
        best: Optional[int] = None
        for m in range(self.n_stations):
            for lo, hi in self.window_sets.get((sat, m), []):
                if hi <= t:
                    continue
                cand = max(lo, t)
                if best is None or cand < best:
                    best = cand
        return best

    def assign(self, t: int,
               demands: Dict[int, Tuple[float, float]]) -> Dict[int, int]:
        """station -> satellite for tick ``t``.  ``demands`` maps each
        satellite to (value, cost): the priority-weighted undelivered
        tokens queued on its downlink lane and the ticks its backlog
        needs at the link rate.  Zero-value satellites are never
        assigned — a station tick spent on an empty lane is a pass
        second another lane could have used."""
        out: Dict[int, int] = {}
        if self.policy == "static":
            for k in range(self.n_satellites):
                m = k % self.n_stations
                v, _ = demands.get(k, (0.0, 1.0))
                if v > 0 and m not in out and self.in_window(k, m, t):
                    out[m] = k
            return out
        cands = []
        for k, m in self.open_pairs(t):
            v, c = demands.get(k, (0.0, 1.0))
            if v <= 0:
                continue
            # deterministic total order: score desc, then sat, station
            cands.append((-(v / max(c, 1.0)), k, m))
        busy_sats: set = set()
        for _, k, m in sorted(cands):
            if m in out or k in busy_sats:
                continue
            out[m] = k
            busy_sats.add(k)
        return out


# ==========================================================================
# handover serialization (checkpoint/store wire format)
# ==========================================================================

def pack_sequence(path: str, entry: SwapEntry, kv,
                  preempted_step: int) -> int:
    """Serialize one spilled sequence through ``checkpoint/store.py`` —
    the single-sequence slice of ``PreemptiveScheduler.checkpoint``'s
    schema (kv leaves + prompt + last logits in the tree; request and
    slot-state fields in the meta).  Returns the on-disk byte count,
    which is what the ISL lane meters."""
    st = entry.state
    req = st.request
    tree: Dict[str, np.ndarray] = {"prompt": np.asarray(req.prompt)}
    n = 0
    if kv is not None:
        leaves = jax.tree.leaves(kv)
        for i, leaf in enumerate(leaves):
            tree[f"kv/{i}"] = np.asarray(leaf)
        n = len(leaves)
    if st.last_logits is not None:
        tree["logits"] = np.asarray(st.last_logits)
    meta = {
        "rid": int(req.rid), "max_new": int(req.max_new),
        "arrival_t": float(req.arrival_t), "priority": int(req.priority),
        "prefill_pos": int(req.prefill_pos),
        "pos": int(st.pos), "next_tok": int(st.next_tok),
        "emitted": [int(x) for x in st.emitted],
        "admitted_step": int(st.admitted_step),
        "first_token_step": int(st.first_token_step),
        "phase": st.phase, "n_preemptions": int(st.n_preemptions),
        "preempted_step": int(preempted_step),
        "n_kv_leaves": n,
        "drafts": [int(x) for x in st.drafts],
    }
    return save_checkpoint(path, tree, meta=meta)


def pack_request(path: str, req: Request) -> int:
    """Serialize a not-yet-admitted request (no KV to move — the
    destination prefills it from scratch)."""
    meta = {
        "rid": int(req.rid), "max_new": int(req.max_new),
        "arrival_t": float(req.arrival_t), "priority": int(req.priority),
        "prefill_pos": 0, "n_kv_leaves": -1,   # -1: queued, not a snapshot
    }
    return save_checkpoint(path, {"prompt": np.asarray(req.prompt)},
                           meta=meta)


def graft_sequence(dst: PreemptiveScheduler, path: str) -> int:
    """Rebuild a shipped sequence on the destination satellite — the
    ``PreemptiveScheduler.restore`` graft for ONE sequence: a fresh
    fully-private ``_PagedSlotState`` budgeted for its whole lifetime
    enters the swap ledger as a spilled entry; the next free slot
    resumes it token-exactly from the shipped KV.  Returns the rid."""
    leaves, meta = load_checkpoint_raw(path)
    rid = int(meta["rid"])
    req = Request(prompt=np.asarray(leaves["prompt"]),
                  max_new=int(meta["max_new"]), rid=rid,
                  arrival_t=float(meta["arrival_t"]),
                  priority=int(meta["priority"]),
                  prefill_pos=int(meta["prefill_pos"]))
    n = int(meta["n_kv_leaves"])
    if n < 0:                                  # queued: no state to graft
        dst.submit(req)
        return rid
    slots = dst.engine.slots
    kv = None
    if n:
        treedef = jax.tree.structure(slots.cache)
        kv = jax.tree.unflatten(
            treedef, [leaves[f"kv/{i}"] for i in range(n)])
    st = _PagedSlotState(
        request=req, pos=int(meta["pos"]), next_tok=int(meta["next_tok"]),
        emitted=[int(x) for x in meta["emitted"]],
        admitted_step=int(meta["admitted_step"]),
        first_token_step=int(meta["first_token_step"]),
        phase=meta["phase"], n_preemptions=int(meta["n_preemptions"]),
        last_logits=leaves.get("logits"),
        drafts=[int(x) for x in meta.get("drafts", [])],
        pages=[], budget=slots._lifetime_pages(req),
        synced_pages=0, shared_pages=0)
    dst.swapped[rid] = SwapEntry(state=st, kv=kv,
                                 preempted_step=int(meta["preempted_step"]),
                                 spilled=True)
    return rid


# ==========================================================================
# the constellation scheduler
# ==========================================================================

@dataclass
class ConstellationReport:
    """Final answers plus the fleet ledger of one constellation replay."""
    tokens: Dict[int, np.ndarray]       # rid -> delivered token stream
    delivered_tick: Dict[int, int]      # rid -> tick the answer landed
    goodput: float                      # delivered tokens / drain ticks
    delivered_tokens: int
    final_clock: int
    n_handovers: int                    # live sequences grafted on a peer
    n_result_forwards: int              # finished answers routed via ISL
    n_handover_redos: int               # corrupt spill record -> redo
    undelivered: List[int]
    fleet: List[Dict[str, float]]       # per-satellite ledger summaries
    fleet_totals: Dict[str, float]
    within_energy_budget: bool
    assigned_pass_ticks: int            # station-ticks granted by the planner
    sat_stats: List[dict] = field(default_factory=list)
    lane_stats: List[dict] = field(default_factory=list)
    isl_stats: List[dict] = field(default_factory=list)


class ConstellationScheduler:
    """K satellite engines, M ground stations, one shared tick clock.

    Per tick: (1) the ``ContactPlanner`` grants stations to the
    highest priority-to-value downlink backlogs; (2) granted lanes
    drain one tick of bytes (framed ARQ when ``frame_bytes`` is set —
    completed result payloads are *delivered*); (3) inter-satellite
    lanes drain (completed handover payloads graft on their
    destination, forwarded results join the destination's downlink
    lane); (4) window-poor satellites hand live sequences to
    window-rich peers; (5) every satellite takes one unified engine
    step (decode when it has work, an idle tick otherwise, so the K
    clocks stay in lockstep).  When the fleet is only waiting on a
    future pass, the clock jumps there — drain time is what goodput is
    measured against.
    """

    def __init__(self, engines: List[ContinuousEngine], *,
                 window_sets: Dict[Tuple[int, int], List[Tuple[int, int]]],
                 n_stations: int, s_per_step: float = 1.0,
                 horizon_s: float = 7200.0, policy: str = "value",
                 handover: bool = True, handover_margin_ticks: int = 64,
                 link: LinkModel = LinkModel(), isl_mbps: float = 100.0,
                 frame_bytes: Optional[int] = None,
                 link_max_retries: int = 8,
                 faults: Optional[FaultInjector] = None,
                 energy: Optional[EnergyModel] = None,
                 spill_codec: Optional[str] = None):
        if not engines:
            raise ValueError("a constellation needs at least one satellite")
        for e in engines:
            if not hasattr(e.slots, "allocator"):
                raise ValueError("constellation handover needs the paged "
                                 "KV layout (spill records are pages)")
            if getattr(e.slots, "prefix_index", None) is not None:
                raise ValueError(
                    "constellation engines must run prefix_cache=False: "
                    "spill records are in private-page coordinates, and a "
                    "shared prefix pinned on the source pool cannot ride "
                    "the handover wire")
        self.n_sats = len(engines)
        self.n_stations = n_stations
        self.s_per_step = s_per_step
        self.horizon_steps = int(horizon_s // s_per_step)
        self.handover = handover
        self.margin = int(handover_margin_ticks)
        self.faults = faults
        if faults is not None:
            window_sets = {pair: faults.truncate_step_windows(list(w))
                           for pair, w in sorted(window_sets.items())}
        self.planner = ContactPlanner(dict(window_sets), self.n_sats,
                                      n_stations, policy=policy)
        self.sats = [PreemptiveScheduler(e, delta_spill=True,
                                         spill_codec=spill_codec,
                                         fault_injector=faults)
                     for e in engines]
        lane_inj = faults if frame_bytes is not None else None
        self.lanes = [TransmitLane(frame_bytes=frame_bytes,
                                   max_retries=link_max_retries,
                                   injector=lane_inj)
                      for _ in engines]
        self.isl = [TransmitLane(frame_bytes=frame_bytes,
                                 max_retries=link_max_retries,
                                 injector=lane_inj)
                    for _ in engines]
        self.bytes_per_step = s_per_step / link.downlink_time_s(1.0)
        self.isl_bytes_per_step = isl_mbps * 1e6 / 8.0 * s_per_step
        self.fleet = FleetEnergy(self.n_sats, energy)
        self._tmp = tempfile.TemporaryDirectory(prefix="constellation_")
        self._n_packed = 0
        # bookkeeping
        self.tokens: Dict[int, np.ndarray] = {}      # finished rid -> toks
        self.delivered_tick: Dict[int, int] = {}
        self._payload_value: Dict[int, float] = {}   # undelivered results
        self._priority: Dict[int, int] = {}          # rid -> Request.priority
        self.n_handovers = 0
        self.n_result_forwards = 0
        self.n_handover_redos = 0
        self.assigned_pass_ticks = 0
        self.last_assignment: Dict[int, int] = {}

    # -- clock / work state --------------------------------------------------
    @property
    def clock(self) -> int:
        return self.sats[0].engine.clock

    def _set_clock(self, t: int) -> None:
        for s in self.sats:
            s.engine.clock = t

    def engine_work(self) -> bool:
        return any(s.has_work() for s in self.sats)

    def lanes_pending(self) -> bool:
        return any(len(l) for l in self.lanes) or any(len(l)
                                                      for l in self.isl)

    def has_work(self) -> bool:
        return self.engine_work() or self.lanes_pending()

    def ownership(self) -> Dict[int, List[int]]:
        """rid -> list of satellites that currently hold the sequence
        (queued, swapped or active).  The property suite gates every
        list at length 1 — a handover must never double-own: the source
        forgets the sequence before the wire ships it, and a payload in
        flight is owned by the wire alone."""
        own: Dict[int, List[int]] = {}
        for k, sat in enumerate(self.sats):
            eng = sat.engine
            rids = ([r.rid for r in eng.queue.items()]
                    + list(sat.swapped)
                    + [eng.slots.states[s].request.rid
                       for s in eng.slots.active_slots()])
            for rid in rids:
                own.setdefault(rid, []).append(k)
        return own

    # -- demand / value accounting ------------------------------------------
    def _lane_demand(self, k: int) -> Tuple[float, float]:
        """(priority-weighted undelivered tokens, ticks of backlog) for
        satellite ``k``'s downlink lane — the planner objective's value
        and pass-cost terms."""
        value = sum(self._payload_value.get(item[1], 0.0)
                    for item in self.lanes[k].pending_items())
        cost = -(-self.lanes[k].pending_bytes() // self.bytes_per_step)
        return value, max(float(cost), 1.0)

    @staticmethod
    def _remaining_tokens(st) -> int:
        return max(st.request.max_new - len(st.emitted), 0)

    # -- tick phases ---------------------------------------------------------
    def _downlink_phase(self, t: int) -> None:
        demands = {k: self._lane_demand(k) for k in range(self.n_sats)}
        self.last_assignment = self.planner.assign(t, demands)
        for m, k in sorted(self.last_assignment.items()):
            lane = self.lanes[k]
            sent0 = lane.bytes_sent
            for item in lane.tick(self.bytes_per_step):
                rid = item[1]
                self.delivered_tick[rid] = t + 1
                self._payload_value.pop(rid, None)
            for item, nbytes in lane.take_failed():
                lane.enqueue(item, nbytes)     # answers are never dropped
            self.fleet.charge_downlink(k, self.s_per_step,
                                       lane.bytes_sent - sent0)
            self.assigned_pass_ticks += 1

    def _isl_phase(self, t: int) -> None:
        for src in range(self.n_sats):
            lane = self.isl[src]
            if not len(lane):
                continue
            sent0 = lane.bytes_sent
            for item in lane.tick(self.isl_bytes_per_step):
                kind, rid, dst = item[0], item[1], item[2]
                if kind == "seq":
                    graft_sequence(self.sats[dst], item[3])
                    os.unlink(item[3])
                else:                          # forwarded finished answer
                    self.lanes[dst].enqueue(
                        ("result", rid),
                        payload_bytes_result(len(self.tokens[rid])))
            for item, nbytes in lane.take_failed():
                lane.enqueue(item, nbytes)
            self.fleet.charge_isl(src, self.s_per_step,
                                  lane.bytes_sent - sent0)

    def _handover_candidate(self, k: int):
        """Highest-value unfinished sequence on satellite ``k``:
        ("active", slot) / ("swapped", rid) / ("queued", req), by
        priority-weighted remaining tokens, rid-tie-broken."""
        sat = self.sats[k]
        eng = sat.engine
        cands = []
        for slot in eng.slots.active_slots():
            st = eng.slots.states[slot]
            cands.append((self._remaining_tokens(st)
                          * priority_weight(st.request.priority),
                          -st.request.rid, "active", slot))
        for rid, e in sat.swapped.items():
            if not e.spilled:
                continue   # resident entries pin source-pool pages; the
                #            default preempt mode here is always "spill"
            cands.append((self._remaining_tokens(e.state)
                          * priority_weight(e.priority),
                          -rid, "swapped", rid))
        for r in eng.queue.arrived(eng.clock):
            cands.append((r.max_new * priority_weight(r.priority),
                          -r.rid, "queued", r))
        cands = [c for c in cands if c[0] > 0]
        return max(cands) if cands else None

    def _ship(self, k: int, dst: int, cand) -> None:
        """Spill -> serialize -> enqueue one sequence on the ISL lane.
        A corrupt spill record takes the redo lane instead (the source
        requeues from prefill; the handover is aborted)."""
        sat = self.sats[k]
        _, _, kind, obj = cand
        path = os.path.join(self._tmp.name, f"ho_{self._n_packed}.ckpt")
        self._n_packed += 1
        if kind == "queued":
            sat.engine.queue.take(obj)
            nbytes = pack_request(path, obj)
            rid = obj.rid
        else:
            if kind == "active":
                rid = sat.preempt(obj, "spill")
            else:
                rid = obj
            entry = sat.swapped.pop(rid)
            kv = entry.kv
            if (kv is None and sat.store is not None
                    and rid in sat.store):
                try:
                    kv = sat.store.snapshot(rid)   # the wire-format record
                except SpillCorruption:
                    sat._redo_corrupt(entry)       # existing redo lane —
                    self.n_handover_redos += 1     # never a garbage graft
                    return
            if sat.store is not None:
                sat.store.drop(rid)                # the source forgets it
            nbytes = pack_sequence(path, entry, kv, entry.preempted_step)
        self.isl[k].enqueue(("seq", rid, dst, path), nbytes)
        self.n_handovers += 1

    def _handover_phase(self, t: int) -> None:
        if not self.handover:
            return
        for k in range(self.n_sats):
            if len(self.isl[k]):               # one transfer in flight
                continue
            if not self.sats[k].has_work():
                continue
            mine = self.planner.next_open(k, t)
            best_peer, best_t = None, None
            for j in range(self.n_sats):
                if j == k:
                    continue
                nxt = self.planner.next_open(j, t)
                if nxt is not None and (best_t is None or nxt < best_t):
                    best_peer, best_t = j, nxt
            if best_peer is None:
                continue
            if mine is not None and mine <= best_t + self.margin:
                continue                       # owner keeps its window
            cand = self._handover_candidate(k)
            if cand is not None:
                self._ship(k, best_peer, cand)

    def _route_result(self, k: int, rid: int, t: int) -> None:
        res = self.sats[k].results[rid]
        toks = np.asarray(res.tokens)
        self.tokens[rid] = toks
        self._payload_value[rid] = (
            len(toks) * priority_weight(self._priority.get(rid, 0)))
        nbytes = payload_bytes_result(len(toks))
        dst = k
        if self.handover and self.planner.policy == "value":
            mine = self.planner.next_open(k, t)
            for j in range(self.n_sats):
                if j == k:
                    continue
                nxt = self.planner.next_open(j, t)
                if nxt is not None and (mine is None
                                        or nxt + self.margin < mine):
                    dst, mine = j, nxt
        if dst == k:
            self.lanes[k].enqueue(("result", rid), nbytes)
        else:
            self.isl[k].enqueue(("result", rid, dst), nbytes)
            self.n_result_forwards += 1

    def _decode_phase(self, t: int) -> None:
        for k, sat in enumerate(self.sats):
            if sat.has_work():
                finished = sat.step(decode=True)
                self.fleet.charge_compute(k, 1, self.s_per_step)
                for rid in finished:
                    self._route_result(k, rid, t)
            else:
                sat.step(decode=False)         # lockstep idle tick

    def _maybe_sleep(self) -> None:
        """Nothing to compute, nothing on the ISL, backlog waiting on a
        pass: jump the shared clock to the earliest useful event (next
        window of a backlogged satellite, or the next arrival)."""
        if self.engine_work() or any(len(l) for l in self.isl):
            return
        t = self.clock
        nxts = [self.planner.next_open(k, t)
                for k in range(self.n_sats) if len(self.lanes[k])]
        nxts = [n for n in nxts if n is not None]
        if nxts:
            nxt = min(nxts)
            if nxt > t:
                self._set_clock(min(nxt, self.horizon_steps))
        elif self.lanes_pending():
            # a backlog with no pass left in the horizon can never land:
            # end the replay; the report surfaces it as undelivered
            self._set_clock(self.horizon_steps)

    def tick(self) -> None:
        t = self.clock
        self._downlink_phase(t)
        self._isl_phase(t)
        self._handover_phase(t)
        self._decode_phase(t)
        self._maybe_sleep()

    # -- the replay ----------------------------------------------------------
    def run(self,
            assignments: List[List[Request]]) -> ConstellationReport:
        """Drain ``assignments`` (``assignments[k]`` arrives via
        satellite ``k``'s uplink) against the window sets, then report.
        """
        if len(assignments) != self.n_sats:
            raise ValueError(f"expected {self.n_sats} per-satellite "
                             f"request lists, got {len(assignments)}")
        for k, reqs in enumerate(assignments):
            for r in sorted(reqs, key=lambda r: r.arrival_t):
                self.sats[k].submit(r)
                self._priority[r.rid] = r.priority
        while self.clock < self.horizon_steps and self.has_work():
            self.tick()
        return self.report()

    def report(self) -> ConstellationReport:
        delivered = sorted(self.delivered_tick)
        undone = set(self.tokens) - set(self.delivered_tick)
        undone |= set(self.ownership())          # unfinished sequences
        for lane in self.isl:                    # payloads still on the wire
            undone |= {item[1] for item in lane.pending_items()}
        undelivered = sorted(undone)
        n_tokens = sum(len(self.tokens[rid]) for rid in delivered)
        clock = max(self.clock, 1)
        horizon_s = self.horizon_steps * self.s_per_step
        return ConstellationReport(
            tokens={rid: self.tokens[rid] for rid in delivered},
            delivered_tick=dict(self.delivered_tick),
            goodput=n_tokens / clock,
            delivered_tokens=n_tokens,
            final_clock=self.clock,
            n_handovers=self.n_handovers,
            n_result_forwards=self.n_result_forwards,
            n_handover_redos=self.n_handover_redos,
            undelivered=undelivered,
            fleet=[dict(l.counters) for l in self.fleet.ledgers],
            fleet_totals=self.fleet.totals(),
            within_energy_budget=self.fleet.within_budget(horizon_s),
            assigned_pass_ticks=self.assigned_pass_ticks,
            sat_stats=[s.stats() for s in self.sats],
            lane_stats=[l.state() for l in self.lanes],
            isl_stats=[l.state() for l in self.isl])
