"""Speculative collaborative decoding — the paper's satellite-ground
cascade applied at TOKEN granularity (beyond-paper).

The onboard (draft) tier proposes k tokens greedily; the ground (target)
tier verifies all k in ONE forward pass and accepts the longest matching
prefix, emitting its own token at the first disagreement.  Greedy
variant: the output is PROVABLY identical to decoding the ground tier
alone — the onboard tier only changes how many expensive ground passes
(and how many uplink round-trips, in the deployment) are needed.

The link ledger mirrors core/cascade.py: each verify round costs one
satellite->ground round trip carrying the drafted ids (tiny) instead of
per-token round trips.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import transformer as T
from repro.core.telemetry import Ledger


@dataclass
class SpecResult:
    tokens: np.ndarray                 # (n_new,) final sequence continuation
    rounds: int
    drafted: int
    accepted: int
    ledger: Ledger = field(default_factory=Ledger)

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.drafted, 1)


def _greedy_next(params, cfg, tokens):
    # serving forward: drop-free MoE routing keeps draft/verify rounds
    # (which see the same prefix at different batch lengths) consistent
    logits, _ = T.forward(params, cfg, {"tokens": tokens},
                          moe_drop_free=True, remat=False)
    return jnp.argmax(logits[:, -1], axis=-1)


def speculative_generate(draft_params, draft_cfg: ModelConfig,
                         target_params, target_cfg: ModelConfig,
                         prompt: np.ndarray, *, max_new: int = 16,
                         k: int = 4) -> SpecResult:
    """prompt: (S,) int32 (single sequence).  Greedy draft-and-verify."""
    assert prompt.ndim == 1
    seq = jnp.asarray(prompt, jnp.int32)[None]          # (1, S)
    produced: List[int] = []
    ledger = Ledger()
    rounds = drafted = accepted = 0

    while len(produced) < max_new:
        # ---- onboard tier drafts k tokens ------------------------------
        dseq = seq
        draft_toks = []
        for _ in range(min(k, max_new - len(produced))):
            nxt = _greedy_next(draft_params, draft_cfg, dseq)
            draft_toks.append(int(nxt[0]))
            dseq = jnp.concatenate([dseq, nxt[None]], axis=1)
        drafted += len(draft_toks)

        # ---- ground tier verifies all drafts in one pass ---------------
        cand = jnp.concatenate(
            [seq, jnp.asarray(draft_toks, jnp.int32)[None]], axis=1)
        logits, _ = T.forward(target_params, target_cfg,
                              {"tokens": cand}, moe_drop_free=True,
                              remat=False)
        # target's next-token prediction at each draft position
        start = seq.shape[1] - 1
        preds = np.asarray(
            jnp.argmax(logits[0, start:start + len(draft_toks) + 1], -1))
        rounds += 1
        ledger.add("verify_rounds", 1)
        ledger.add("uplink_bytes", 4 * len(draft_toks) + 16)

        n_ok = 0
        for d, p in zip(draft_toks, preds[:-1]):
            if d == int(p):
                n_ok += 1
            else:
                break
        accepted += n_ok
        out = draft_toks[:n_ok] + [int(preds[n_ok])]     # correction token
        out = out[:max_new - len(produced)]
        produced.extend(out)
        seq = jnp.concatenate(
            [seq, jnp.asarray(out, jnp.int32)[None]], axis=1)

    ledger.add("tokens_produced", len(produced))
    return SpecResult(tokens=np.asarray(produced, np.int64), rounds=rounds,
                      drafted=drafted, accepted=accepted, ledger=ledger)


def greedy_generate(params, cfg: ModelConfig, prompt: np.ndarray,
                    max_new: int = 16) -> np.ndarray:
    """Reference: plain greedy decoding of one sequence (full forwards)."""
    seq = jnp.asarray(prompt, jnp.int32)[None]
    out = []
    for _ in range(max_new):
        nxt = _greedy_next(params, cfg, seq)
        out.append(int(nxt[0]))
        seq = jnp.concatenate([seq, nxt[None]], axis=1)
    return np.asarray(out, np.int64)
