"""Speculative collaborative decoding — the paper's satellite-ground
cascade applied at TOKEN granularity (beyond-paper).

The onboard (draft) tier proposes k tokens greedily; the ground (target)
tier verifies all k in ONE paged-attention pass and accepts the longest
matching prefix, emitting its own token at the first disagreement.
Greedy variant: the output is PROVABLY identical to decoding the ground
tier alone — the onboard tier only changes how many expensive ground
passes (and how many uplink round-trips, in the deployment) are needed.

Both tiers run on ``serving.engine.ContinuousEngine``, so every token is
KV-cached: the draft tier decodes k tokens at O(1) model work each, and
the target tier verifies them through the SAME ``prefill_chunk`` path
that admits prompts — one chunk of ``[last_token, d_1..d_k]`` written
straight into the target's paged KV, per-position argmaxes read back.
(The pre-engine version of this module re-ran a full O(S^2) forward per
drafted token on both tiers; nothing here re-processes the prefix.)

The link ledger mirrors core/cascade.py: each verify round costs one
satellite->ground round trip carrying the drafted ids
(``core.link.payload_bytes_draft`` — tiny) instead of per-token round
trips, and only drafts that can actually be emitted are ever shipped or
metered (a final round near ``max_new`` drafts fewer tokens instead of
drafting ahead and truncating).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.config import ModelConfig
from repro.core.link import payload_bytes_draft
from repro.core.telemetry import Ledger
from repro.serving.batching import Request
from repro.serving.engine import DECODING, ContinuousEngine
from repro.serving.paging import pages_for


@dataclass
class SpecResult:
    tokens: np.ndarray                 # (n_new,) int32 final continuation
    rounds: int
    drafted: int
    accepted: int
    ledger: Ledger = field(default_factory=Ledger)

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.drafted, 1)


def _one_shot_engine(cfg: ModelConfig, params, S: int, max_new: int, *,
                     draft_k: int = 8) -> ContinuousEngine:
    """A single-slot engine sized exactly for one (S, max_new) request
    (paged families get a pool covering the whole reservation, so
    admission can never block)."""
    max_seq = S + max_new
    return ContinuousEngine(cfg, params, n_slots=1, max_seq=max_seq,
                            page_size=16,
                            pool_pages=pages_for(max_seq, 16) + 1,
                            prefill_budget_tokens=None, draft_k=draft_k)


def _slot_of(eng: ContinuousEngine, rid: int) -> Optional[int]:
    for i in eng.slots.active_slots():
        if eng.slots.states[i].request.rid == rid:
            return i
    return None


def _run_to_decoding(eng: ContinuousEngine, rid: int) -> Optional[int]:
    """Step until ``rid`` occupies a DECODING slot (its prompt is fully
    prefilled); None when it finished outright (tiny ``max_new``)."""
    while rid not in eng.results:
        slot = _slot_of(eng, rid)
        if slot is not None and eng.slots.states[slot].phase == DECODING:
            return slot
        eng.step()
    return None


def _emitted(eng: ContinuousEngine, rid: int, slot) -> List[int]:
    if rid in eng.results:
        return [int(t) for t in eng.results[rid].tokens]
    return [int(t) for t in eng.slots.states[slot].emitted]


class SpeculativeDecoder:
    """Drives a draft engine and a target engine through one greedy
    draft-and-verify generation.

    The draft engine's KV is steered along the TARGET's accepted stream:
    after each verify round the decoder rewinds the draft slot's
    position/input to the last accepted token — the paged layout masks
    everything beyond ``kv_len``, so rejected draft KV needs no cleanup
    — and force-writes (one tiny chunk, logits discarded) any accepted
    position whose KV the draft tier never produced itself (the bonus
    position of a fully accepted round).  Both engines must use the
    paged KV layout (the verify and force-write passes run through the
    chunk machinery).
    """

    def __init__(self, draft_engine: ContinuousEngine,
                 target_engine: ContinuousEngine, *, k: int = 4):
        if k < 1:
            raise ValueError("k must be >= 1 draft tokens per round")
        if k > target_engine.draft_k:
            raise ValueError(
                f"k={k} exceeds the target engine's draft_k="
                f"{target_engine.draft_k} — rounds would need multiple "
                "verify passes and the accounting below assumes one")
        for name, eng in (("draft", draft_engine),
                          ("target", target_engine)):
            if eng.kv_layout != "paged":
                raise NotImplementedError(
                    f"speculative decoding needs the paged KV layout on "
                    f"the {name} engine (family {eng.cfg.family!r} is "
                    "served contiguously)")
        self.draft = draft_engine
        self.target = target_engine
        self.k = k

    # -- draft-side KV steering --------------------------------------------
    def _force_extend(self, slot: int, toks, pos: int) -> None:
        """Write the KV of already-known tokens at positions
        [pos, pos + len(toks)) of the draft slot through the chunk
        path, discarding the logits — the catch-up for accepted tokens
        the draft engine never ran (the bonus token of a fully accepted
        round lands in the target's stream without a draft forward)."""
        eng = self.draft
        st = eng.slots.states[slot]
        n = len(toks)
        Cb = eng._chunk_bucket(n)
        buf = np.zeros((1, Cb), np.int32)
        buf[0, :n] = toks
        st.pos = int(pos)
        eng.slots.grow_for_chunk(slot, pos + n)
        _, eng.slots.cache = eng._run_chunk(
            buf, n, pos, eng.slots.chunk_block_table(slot))

    # -- the draft-verify loop ---------------------------------------------
    def generate(self, prompt: np.ndarray, max_new: int = 16) -> SpecResult:
        prompt = np.asarray(prompt)
        if prompt.ndim != 1:
            raise ValueError(
                f"prompt must be a single (S,) token sequence, got shape "
                f"{prompt.shape}")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        prompt = prompt.astype(np.int32)
        S = len(prompt)
        ledger = Ledger()
        rounds = drafted = accepted = 0
        tgt, drf = self.target, self.draft

        t_rid = tgt.submit(Request(prompt=prompt.copy(), max_new=max_new))
        t_slot = _run_to_decoding(tgt, t_rid)
        produced = _emitted(tgt, t_rid, t_slot)

        # the draft request's own continuation is discarded — its budget
        # only needs to keep the slot alive (never auto-finishing) while
        # the decoder steers it along the target's stream
        d_rid = drf.submit(Request(prompt=prompt.copy(),
                                   max_new=max_new + self.k + 2))
        d_slot = _run_to_decoding(drf, d_rid)
        d_synced = S           # draft-KV positions [0, d_synced) hold the
        #                        accepted (true) stream's inputs

        while len(produced) < max_new and t_rid not in tgt.results:
            rem = max_new - len(produced)
            k_eff = min(self.k, rem - 1)
            if k_eff < 1:
                tgt.step()     # the final token: nothing left to draft
                produced = _emitted(tgt, t_rid, t_slot)
                continue

            # steer the draft slot onto the accepted stream
            need = S + len(produced) - 1
            if need > d_synced:
                true_stream = np.concatenate(
                    [prompt, np.asarray(produced, np.int32)])
                self._force_extend(d_slot, true_stream[d_synced:need],
                                   d_synced)
                d_synced = need
            dst = drf.slots.states[d_slot]
            dst.pos = need
            dst.next_tok = int(produced[-1])
            dst.emitted = list(produced)

            # onboard tier drafts k_eff tokens, one KV-cached step each
            for _ in range(k_eff):
                drf.step()
            draft_toks = drf.slots.states[d_slot].emitted[len(produced):]
            drafted += k_eff

            # ground tier verifies all of them in ONE chunk pass
            n_shipped = tgt.attach_drafts(t_slot, draft_toks)
            before = len(produced)
            tgt.step()
            produced = _emitted(tgt, t_rid, t_slot)
            n_ok = len(produced) - before - 1
            accepted += n_ok
            rounds += 1
            ledger.add("verify_rounds", 1)
            ledger.add("uplink_bytes", payload_bytes_draft(n_shipped))
            # drafting wrote true inputs up to the first rejection (or,
            # on full acceptance, up to the last draft's position; the
            # bonus position is force-written next round)
            d_synced = need + min(n_ok + 1, k_eff)

        if _slot_of(drf, d_rid) is not None:
            drf.slots.evict(d_slot)           # return the draft pages
        ledger.add("tokens_produced", len(produced))
        return SpecResult(tokens=np.asarray(produced, np.int32),
                          rounds=rounds, drafted=drafted, accepted=accepted,
                          ledger=ledger)


def speculative_generate(draft_params, draft_cfg: ModelConfig,
                         target_params, target_cfg: ModelConfig,
                         prompt: np.ndarray, *, max_new: int = 16,
                         k: int = 4) -> SpecResult:
    """prompt: (S,) int32 (single sequence).  Greedy draft-and-verify;
    ``tokens`` is provably identical to ``greedy_generate`` on the
    target tier alone."""
    prompt = np.asarray(prompt)
    if prompt.ndim != 1:
        raise ValueError(
            f"prompt must be a single (S,) token sequence, got shape "
            f"{prompt.shape}")
    if k < 1:
        raise ValueError("k must be >= 1 draft tokens per round")
    S = len(prompt)
    drf = _one_shot_engine(draft_cfg, draft_params, S, max_new + k + 2)
    tgt = _one_shot_engine(target_cfg, target_params, S, max_new, draft_k=k)
    return SpeculativeDecoder(drf, tgt, k=k).generate(prompt, max_new)


def greedy_generate(params, cfg: ModelConfig, prompt: np.ndarray,
                    max_new: int = 16) -> np.ndarray:
    """Reference: plain greedy decoding of one sequence (KV-cached
    through the same engine the speculative path runs on)."""
    prompt = np.asarray(prompt)
    if prompt.ndim != 1:
        raise ValueError(
            f"prompt must be a single (S,) token sequence, got shape "
            f"{prompt.shape}")
    eng = _one_shot_engine(cfg, params, len(prompt), max_new)
    res = eng.run([Request(prompt=prompt.astype(np.int32),
                           max_new=max_new)])
    (result,) = res.values()
    return np.asarray(result.tokens, np.int32)
