"""Serving engine: prefill + KV-cache decode for any assigned arch.

A fixed-slot batched engine (the satellite tier serves small batches;
the ground tier large ones).  ``generate`` runs prompt prefill once,
grafts the prefix cache into a full-length cache, then steps the
jit-compiled ``decode_step``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import transformer as T


def _graft(template: jax.Array, got: jax.Array) -> jax.Array:
    """Insert ``got`` into zeroed ``template`` along the (single) axis
    where their shapes differ (the cache sequence axis)."""
    if template.shape == got.shape:
        return got.astype(template.dtype)
    diff = [i for i, (a, b) in enumerate(zip(template.shape, got.shape))
            if a != b]
    assert len(diff) == 1, (template.shape, got.shape)
    return jax.lax.dynamic_update_slice_in_dim(
        template, got.astype(template.dtype), 0, axis=diff[0])


@dataclass
class GenerateResult:
    tokens: np.ndarray                 # (B, n_new)
    logits_last: np.ndarray            # (B, V) final-step logits
    prompt_logits: np.ndarray          # (B, V) last prompt-position logits


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_seq: int = 2048):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(
            lambda p, b: T.prefill(p, cfg, b))
        self._decode = jax.jit(
            lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos))

    @classmethod
    def init(cls, cfg: ModelConfig, seed: int = 0, max_seq: int = 2048):
        params = T.init_params(jax.random.PRNGKey(seed), cfg,
                               max_seq=max_seq)
        return cls(cfg, params, max_seq=max_seq)

    def full_cache(self, prompt_cache, batch: int):
        template = T.init_cache(self.cfg, batch, self.max_seq)
        return jax.tree.map(_graft, template, prompt_cache)

    def generate(self, tokens: np.ndarray, *, max_new: int = 16,
                 greedy: bool = True, extra_inputs: Optional[dict] = None,
                 seed: int = 0) -> GenerateResult:
        """tokens: (B, S_prompt) int32."""
        cfg = self.cfg
        B, S = tokens.shape
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        logits, cache = self._prefill(self.params, batch)
        cache = self.full_cache(cache, B)
        prompt_logits = np.asarray(logits[:, -1], np.float32)

        key = jax.random.PRNGKey(seed)
        pos = S
        if cfg.family == "vlm":
            pos = S + (extra_inputs or {}).get(
                "patch_embeds", np.zeros((B, 0, 1))).shape[1]
        out = np.empty((B, max_new), np.int64)
        cur_logits = logits[:, -1]
        for t in range(max_new):
            if greedy:
                nxt = jnp.argmax(cur_logits, axis=-1)
            else:
                key, sk = jax.random.split(key)
                nxt = jax.random.categorical(sk, cur_logits)
            out[:, t] = np.asarray(nxt)
            step_logits, cache = self._decode(
                self.params, cache, nxt[:, None].astype(jnp.int32),
                jnp.int32(pos + t))
            cur_logits = step_logits[:, 0]
        return GenerateResult(tokens=out,
                              logits_last=np.asarray(cur_logits, np.float32),
                              prompt_logits=prompt_logits)
