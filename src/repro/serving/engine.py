"""Serving engines: prefill + KV-cache decode for any assigned arch.

Two engines share the model's cache layout contract:

  * ``ServingEngine`` — fixed-slot batches (seed behavior): every
    request is padded to the longest prompt and the whole batch drains
    before the next one starts.  The satellite tier serves small
    batches (latency/power bound); fine there.
  * ``ContinuousEngine`` — continuous batching for the throughput-bound
    ground tier: a ``SlotManager`` owns one ``(n_slots, ..., max_seq,
    ...)`` KV cache; requests are prefilled individually, grafted into
    whichever slot is free, and all active slots step together through
    ONE jit-compiled ``decode_step`` with per-slot position vectors.
    Finished sequences are evicted immediately so queued arrivals join
    mid-flight instead of waiting for a batch to drain.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import transformer as T
from repro.serving.batching import Request, RequestQueue


def _graft(template: jax.Array, got: jax.Array) -> jax.Array:
    """Insert ``got`` into zeroed ``template`` along the (single) axis
    where their shapes differ (the cache sequence axis)."""
    if template.shape == got.shape:
        return got.astype(template.dtype)
    diff = [i for i, (a, b) in enumerate(zip(template.shape, got.shape))
            if a != b]
    assert len(diff) == 1, (template.shape, got.shape)
    return jax.lax.dynamic_update_slice_in_dim(
        template, got.astype(template.dtype), 0, axis=diff[0])


@dataclass
class GenerateResult:
    tokens: np.ndarray                 # (B, n_new)
    logits_last: np.ndarray            # (B, V) final-step logits
    prompt_logits: np.ndarray          # (B, V) last prompt-position logits


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_seq: int = 2048):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(
            lambda p, b: T.prefill(p, cfg, b))
        self._decode = jax.jit(
            lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos))

    @classmethod
    def init(cls, cfg: ModelConfig, seed: int = 0, max_seq: int = 2048):
        params = T.init_params(jax.random.PRNGKey(seed), cfg,
                               max_seq=max_seq)
        return cls(cfg, params, max_seq=max_seq)

    def full_cache(self, prompt_cache, batch: int):
        template = T.init_cache(self.cfg, batch, self.max_seq)
        return jax.tree.map(_graft, template, prompt_cache)

    def generate(self, tokens: np.ndarray, *, max_new: int = 16,
                 greedy: bool = True, extra_inputs: Optional[dict] = None,
                 seed: int = 0) -> GenerateResult:
        """tokens: (B, S_prompt) int32."""
        cfg = self.cfg
        B, S = tokens.shape
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        logits, cache = self._prefill(self.params, batch)
        cache = self.full_cache(cache, B)
        prompt_logits = np.asarray(logits[:, -1], np.float32)

        key = jax.random.PRNGKey(seed)
        pos = S
        if cfg.family == "vlm":
            pos = S + (extra_inputs or {}).get(
                "patch_embeds", np.zeros((B, 0, 1))).shape[1]
        out = np.empty((B, max_new), np.int64)
        cur_logits = logits[:, -1]
        for t in range(max_new):
            if greedy:
                nxt = jnp.argmax(cur_logits, axis=-1)
            else:
                key, sk = jax.random.split(key)
                nxt = jax.random.categorical(sk, cur_logits)
            out[:, t] = np.asarray(nxt)
            step_logits, cache = self._decode(
                self.params, cache, nxt[:, None].astype(jnp.int32),
                jnp.int32(pos + t))
            cur_logits = step_logits[:, 0]
        return GenerateResult(tokens=out,
                              logits_last=np.asarray(cur_logits, np.float32),
                              prompt_logits=prompt_logits)


# ==========================================================================
# continuous batching
# ==========================================================================

@dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray                 # (n_new,) greedy continuation
    prompt_len: int
    admitted_step: int                 # engine clock at admission
    finished_step: int = 0


@dataclass
class _SlotState:
    request: Request
    pos: int                           # absolute position of the NEXT write
    next_tok: int                      # last emitted token (next decode input)
    emitted: List[int] = field(default_factory=list)
    admitted_step: int = 0


class SlotManager:
    """Owns the multi-slot KV cache and per-slot occupancy.

    The cache is ``models.transformer.init_cache(cfg, n_slots, max_seq)``
    — slot ``i`` is batch row ``i`` of every leaf.  Admission grafts a
    single-sequence prefix cache into a free slot; eviction just frees
    the slot id: stale keys/values beyond a new occupant's prefix are
    masked out by the per-slot ``kv_len`` until overwritten.
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, max_seq: int):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.cache = T.init_cache(cfg, n_slots, max_seq)
        self.states: List[Optional[_SlotState]] = [None] * n_slots
        self._graft = jax.jit(T.graft_slot_cache)

    # -- occupancy ---------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.states) if s is None]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.states) if s is not None]

    def any_active(self) -> bool:
        return any(s is not None for s in self.states)

    # -- admission / eviction ---------------------------------------------
    def place(self, slot: int, prefix_cache, state: _SlotState) -> None:
        assert self.states[slot] is None, f"slot {slot} occupied"
        self.cache = self._graft(self.cache, prefix_cache, jnp.int32(slot))
        self.states[slot] = state

    def evict(self, slot: int) -> None:
        self.states[slot] = None

    # -- batched decode inputs --------------------------------------------
    def decode_inputs(self):
        """(tokens (n_slots, 1) int32, pos (n_slots,) int32).  Inactive
        slots feed a dummy token at position 0 of their own (private)
        cache row, leaving live garbage there.  That is safe ONLY because
        ``place``'s graft rewrites positions [0, prefix) before the slot
        is read again — any future layout change (e.g. paged KV) must
        preserve an equivalent overwrite-before-read guarantee."""
        toks = np.zeros((self.n_slots, 1), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        for i, s in enumerate(self.states):
            if s is not None:
                toks[i, 0] = s.next_tok
                pos[i] = s.pos
        return toks, pos


class ContinuousEngine:
    """Continuous-batching greedy decoding.

    Supported families: dense / moe (incl. MLA) / hybrid / ssm.  vlm and
    audio need per-request side inputs (patch embeds, encoder frames)
    and are served by the fixed-slot engine.

    Attention-cached families bucket prompts (right-padded to the next
    power of two) so admission prefills hit a handful of compiled
    shapes; causal masking plus per-slot ``kv_len`` make the pad
    positions invisible.  Recurrent families (hybrid/ssm) prefill at the
    exact prompt length — their prefix state integrates every input
    position, so padding would change it.
    """

    FAMILIES = ("dense", "moe", "hybrid", "ssm")

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 4,
                 max_seq: int = 2048, queue_capacity: Optional[int] = None):
        if cfg.family not in self.FAMILIES:
            raise NotImplementedError(
                f"ContinuousEngine does not serve family {cfg.family!r}")
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.slots = SlotManager(cfg, n_slots, max_seq)
        self.queue = RequestQueue(max_batch=n_slots,
                                  capacity=queue_capacity)
        self.clock = 0                        # decode-step ticks
        self.finish_order: List[int] = []
        self.results: Dict[int, RequestResult] = {}
        self._prefill = jax.jit(
            lambda p, t: T.forward(p, cfg, {"tokens": t},
                                   moe_drop_free=True,
                                   return_cache=True, remat=False))
        self._decode = jax.jit(
            lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos))

    @classmethod
    def init(cls, cfg: ModelConfig, seed: int = 0, **kw):
        params = T.init_params(jax.random.PRNGKey(seed), cfg,
                               max_seq=kw.get("max_seq", 2048))
        return cls(cfg, params, **kw)

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request) -> int:
        if req.max_new < 1:
            raise ValueError(
                f"request {req.rid}: max_new must be >= 1 "
                "(the prefill always emits one token)")
        if len(req.prompt) + req.max_new > self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + max_new "
                f"{req.max_new} exceeds max_seq {self.max_seq}")
        return self.queue.submit(req)

    def _bucket_len(self, S: int) -> int:
        if self.cfg.family in ("hybrid", "ssm"):
            return S                          # recurrent state is length-exact
        b = 8
        while b < S:
            b *= 2
        return min(b, self.max_seq)

    def _admit(self, req: Request, slot: int) -> None:
        S = len(req.prompt)
        bucket = self._bucket_len(S)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :S] = req.prompt
        logits, _, pcache = self._prefill(self.params, jnp.asarray(toks))
        first = int(jnp.argmax(logits[0, S - 1]))
        st = _SlotState(request=req, pos=S, next_tok=first, emitted=[first],
                        admitted_step=self.clock)
        self.slots.place(slot, pcache, st)
        if len(st.emitted) >= req.max_new:    # max_new == 1: done at prefill
            self._finish(slot)

    def _finish(self, slot: int) -> None:
        st = self.slots.states[slot]
        req = st.request
        self.results[req.rid] = RequestResult(
            rid=req.rid, tokens=np.asarray(st.emitted, np.int64),
            prompt_len=len(req.prompt), admitted_step=st.admitted_step,
            finished_step=self.clock)
        self.finish_order.append(req.rid)
        self.slots.evict(slot)

    # -- the serve loop ----------------------------------------------------
    def step(self) -> List[int]:
        """Admit arrived requests into free slots, run ONE batched decode
        step over all slots, evict finished sequences.  Returns the rids
        finished during this step."""
        before = len(self.finish_order)
        for slot in self.slots.free_slots():
            req = self.queue.peek()
            if req is None or req.arrival_t > self.clock:
                break
            self._admit(self.queue.pop(), slot)
        if not self.slots.any_active():
            self.clock += 1                   # idle tick: wait for arrivals
            return self.finish_order[before:]
        toks, pos = self.slots.decode_inputs()
        logits, self.slots.cache = self._decode(
            self.params, self.slots.cache, jnp.asarray(toks),
            jnp.asarray(pos))
        self.clock += 1
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1))
        for slot in self.slots.active_slots():
            st = self.slots.states[slot]
            st.emitted.append(int(nxt[slot]))
            st.next_tok = int(nxt[slot])
            st.pos += 1
            if len(st.emitted) >= st.request.max_new:
                self._finish(slot)
        return self.finish_order[before:]

    def run(self, requests: Optional[List[Request]] = None
            ) -> Dict[int, RequestResult]:
        """Drain: submit ``requests`` (sorted by arrival), then step until
        queue and slots are empty.  Returns rid -> RequestResult."""
        for r in sorted(requests or [], key=lambda r: r.arrival_t):
            self.submit(r)
        while len(self.queue) or self.slots.any_active():
            self.step()
        return self.results
