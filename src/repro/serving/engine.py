"""Serving engines: prefill + KV-cache decode for any assigned arch.

Two engines share the model's cache layout contract:

  * ``ServingEngine`` — fixed-slot batches (seed behavior): every
    request is padded to the longest prompt and the whole batch drains
    before the next one starts.  The satellite tier serves small
    batches (latency/power bound); fine there.
  * ``ContinuousEngine`` — continuous batching driven by ONE *unified
    token-budget step*: every tick runs a mixed batch of (a) up to
    ``prefill_budget_tokens`` prefill-chunk tokens for admitting
    (PREFILLING) sequences and (b) one decode token per DECODING slot,
    so no tick runs more than ``budget + n_slots`` real tokens of
    model work (jit bucketing may round a chunk's executed width up to
    the next power of two — a constant per-engine factor, and exact
    for the default power-of-two budget) — a long arriving prompt can
    no longer stall in-flight decodes (or a contact pass's transmit
    lane) for its whole length.  Finished sequences are evicted
    immediately so queued arrivals join mid-flight instead of waiting
    for a batch to drain.

The continuous engine's KV memory comes in two layouts:

  * ``PagedSlotManager`` (default for dense/moe): a ``BlockAllocator``
    owns a global pool of fixed-size KV pages; each sequence holds a
    growable block table, so memory scales with
    ``sum_i ceil(len_i/page_size)`` instead of ``n_slots * max_seq`` and
    admission blocks on page exhaustion rather than slot count.
    Admission reserves the lifetime page budget but copies NOTHING:
    prompt chunks are written straight into incrementally allocated
    pages by ``models.transformer.prefill_chunk`` — the old
    whole-prompt prefill + template graft path is gone.
  * ``SlotManager`` (recurrent hybrid/ssm, and the memory baseline):
    one contiguous ``(n_slots, ..., max_seq, ...)`` cache row per slot.
    Recurrent prefix state integrates every input position, so these
    families keep monolithic prefill-at-admission (grafted into the
    slot row); their ticks are bounded by the family's fixed state
    size, not by prompt length chunking.

MoE serving prefill uses a *dynamic* per-chunk expert-capacity bound:
it starts near the mean load and doubles on overflow (reported through
the aux channel) until no routing is dropped — token-exact with the
static drop-free worst case (``C = G``) at a fraction of the dispatch
tensor size.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.launch.sharding import (SERVING_LOGICAL_MAP, paged_cache_pspecs,
                                   params_pspecs)
from repro.models import moe as M
from repro.models import transformer as T
from repro.models.pspec import mesh_rules, shard_count
from repro.serving.batching import Request, RequestQueue
from repro.serving.paging import (BlockAllocator, PagePrefixIndex,
                                  default_pool_pages, pages_for,
                                  per_device_pool_stats)

# Jitted engine callables shared across engine instances serving the
# same (hashable, frozen) ModelConfig: benchmark A/B replays and test
# sweeps construct many short-lived engines, and per-instance lambdas
# would recompile identical programs every time.  Keys carry the mesh
# FINGERPRINT alongside the config: a sharded engine's traces bake
# ``with_sharding_constraint`` ops into the jaxpr, so a sharded and an
# unsharded engine serving the same config must never share a callable.
_JIT_CACHE: Dict[tuple, object] = {}


def _cached_jit(key: tuple, make):
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = _JIT_CACHE[key] = make()
    return fn


def _mesh_fingerprint(mesh) -> Optional[tuple]:
    """Hashable identity of a mesh for jit-cache keys: axis names, axis
    sizes AND the concrete device ids — two meshes over different device
    subsets must not share compiled programs."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names),
            tuple(int(mesh.shape[a]) for a in mesh.axis_names),
            tuple(int(d.id) for d in mesh.devices.flat))


def _mesh_wrap(mesh, logical_map, fn):
    """Run ``fn`` with the engine's mesh rules installed, so the
    ``models.pspec.shard`` annotations inside the traced computation
    resolve against the serving mesh (trace-time; later calls hit the
    jit cache and the context is a cheap dict swap)."""
    if mesh is None:
        return fn

    def wrapped(*args, **kw):
        with mesh_rules(mesh, logical_map):
            return fn(*args, **kw)
    return wrapped


def _dynamic_capacity_prefill(prefill_fn, cfg: ModelConfig, n_tok: int):
    """Drop-free MoE prefill under a dynamic per-batch expert-capacity
    bound: start near the mean load and double on overflow until
    token-exact with the unbounded drop-free path.  ``prefill_fn(cap)``
    must return ``(logits, aux, cache)`` where aux counts overflowed
    routings (see ``moe.moe_fwd``); ``cap >= n_tok`` forces the exact
    drop-free worst case in ``moe_fwd``, so the loop always terminates
    with an exact result."""
    cap = M.initial_capacity(cfg, n_tok)
    while True:
        logits, aux, cache = prefill_fn(cap)
        if cap >= n_tok or float(aux) == 0.0:
            return logits, cache
        cap = min(cap * 2, n_tok)


def _graft(template: jax.Array, got: jax.Array) -> jax.Array:
    """Insert ``got`` into zeroed ``template`` along the (single) axis
    where their shapes differ (the cache sequence axis)."""
    if template.shape == got.shape:
        return got.astype(template.dtype)
    diff = [i for i, (a, b) in enumerate(zip(template.shape, got.shape))
            if a != b]
    assert len(diff) == 1, (template.shape, got.shape)
    return jax.lax.dynamic_update_slice_in_dim(
        template, got.astype(template.dtype), 0, axis=diff[0])


@dataclass
class GenerateResult:
    tokens: np.ndarray                 # (B, n_new)
    logits_last: np.ndarray            # (B, V) final-step logits
    prompt_logits: np.ndarray          # (B, V) last prompt-position logits


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_seq: int = 2048):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self._prefill = _cached_jit(("fixed_prefill", cfg), lambda: jax.jit(
            lambda p, b: T.prefill(p, cfg, b)))
        self._prefill_cap = _cached_jit(("fixed_prefill_cap", cfg),
                                        lambda: jax.jit(
            lambda p, b, cap: T.forward(p, cfg, b, moe_drop_free=True,
                                        moe_capacity=cap, return_cache=True,
                                        remat=False),
            static_argnums=(2,)))
        self._decode = _cached_jit(("fixed_decode", cfg), lambda: jax.jit(
            lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos)))

    def _moe_prefill(self, batch):
        n_tok = int(np.prod(batch["tokens"].shape))
        logits, cache = _dynamic_capacity_prefill(
            lambda cap: self._prefill_cap(self.params, batch, cap),
            self.cfg, n_tok)
        return logits[:, -1:], cache

    @classmethod
    def init(cls, cfg: ModelConfig, seed: int = 0, max_seq: int = 2048):
        params = T.init_params(jax.random.PRNGKey(seed), cfg,
                               max_seq=max_seq)
        return cls(cfg, params, max_seq=max_seq)

    def full_cache(self, prompt_cache, batch: int):
        template = T.init_cache(self.cfg, batch, self.max_seq)
        return jax.tree.map(_graft, template, prompt_cache)

    def generate(self, tokens: np.ndarray, *, max_new: int = 16,
                 greedy: bool = True, extra_inputs: Optional[dict] = None,
                 seed: int = 0) -> GenerateResult:
        """tokens: (B, S_prompt) int32."""
        cfg = self.cfg
        B, S = tokens.shape
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        if cfg.moe is not None:
            logits, cache = self._moe_prefill(batch)
        else:
            logits, cache = self._prefill(self.params, batch)
        cache = self.full_cache(cache, B)
        prompt_logits = np.asarray(logits[:, -1], np.float32)

        key = jax.random.PRNGKey(seed)
        pos = S
        if cfg.family == "vlm":
            pos = S + (extra_inputs or {}).get(
                "patch_embeds", np.zeros((B, 0, 1))).shape[1]
        out = np.empty((B, max_new), np.int32)
        cur_logits = logits[:, -1]
        for t in range(max_new):
            if greedy:
                nxt = jnp.argmax(cur_logits, axis=-1)
            else:
                key, sk = jax.random.split(key)
                nxt = jax.random.categorical(sk, cur_logits)
            out[:, t] = np.asarray(nxt)
            step_logits, cache = self._decode(
                self.params, cache, nxt[:, None].astype(jnp.int32),
                jnp.int32(pos + t))
            cur_logits = step_logits[:, 0]
        return GenerateResult(tokens=out,
                              logits_last=np.asarray(cur_logits, np.float32),
                              prompt_logits=prompt_logits)


# ==========================================================================
# continuous batching
# ==========================================================================

@dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray                 # (n_new,) greedy continuation
    prompt_len: int
    admitted_step: int                 # engine clock at admission
    finished_step: int = 0
    first_token_step: int = 0          # clock when the prefill completed
    #                                    and the first token was emitted
    n_preemptions: int = 0             # times swapped out mid-decode
    logits_last: Optional[np.ndarray] = None   # (V,) final-step logits


# lifecycle phases of a slot-resident sequence: PREFILLING sequences are
# still streaming prompt chunks into the cache (no token emitted yet —
# they contribute prefill-chunk tokens to the unified step, not decode
# tokens); DECODING sequences step one token per tick.
PREFILLING = "prefill"
DECODING = "decode"


@dataclass
class _SlotState:
    request: Request
    pos: int                           # absolute position of the NEXT write
    next_tok: int                      # last emitted token (next decode input)
    emitted: List[int] = field(default_factory=list)
    admitted_step: int = 0
    first_token_step: int = 0          # clock at prefill completion
    phase: str = DECODING              # PREFILLING | DECODING
    n_preemptions: int = 0
    last_logits: Optional[np.ndarray] = None   # (V,) set at admission and
    #                                            finish (confidence routing)
    drafts: List[int] = field(default_factory=list)
    #                                  pending speculative draft tokens: the
    #                                  unified step verifies up to ``draft_k``
    #                                  of them in ONE prefill-chunk pass
    #                                  instead of stepping this slot's decode


class _SlotOccupancy:
    """Shared slot-occupancy bookkeeping for both cache layouts."""

    # -- occupancy ---------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.states) if s is None]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.states) if s is not None]

    def decoding_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.states)
                if s is not None and s.phase == DECODING]

    def prefilling_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.states)
                if s is not None and s.phase == PREFILLING]

    def any_active(self) -> bool:
        return any(s is not None for s in self.states)

    # -- batched decode inputs --------------------------------------------
    def decode_inputs(self, skip=()):
        """(tokens (n_slots, 1) int32, pos (n_slots,) int32).  Inactive
        and PREFILLING slots — and ``skip`` slots, which already took a
        multi-token verify pass this tick — feed a dummy token at
        position 0 of a cache region no live sequence reads (their own
        private cache row here; the scratch page in the paged layout —
        ``block_tables`` maps non-decoding rows entirely to the scratch
        page), leaving live garbage there.  That is safe ONLY because
        admission rewrites positions [0, prefix) before the slot is
        read again and everything past a slot's ``kv_len`` is masked —
        any layout must preserve this overwrite-before-read
        guarantee."""
        toks = np.zeros((self.n_slots, 1), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        for i, s in enumerate(self.states):
            if s is not None and s.phase == DECODING and i not in skip:
                toks[i, 0] = s.next_tok
                pos[i] = s.pos
        return toks, pos

    def kv_cache_stats(self) -> dict:
        leaves = jax.tree.leaves(self.cache)
        per_dev = 0
        n_shards = 1
        for l in leaves:
            itemsize = jnp.dtype(l.dtype).itemsize
            if hasattr(l, "sharding"):        # one device's slice of the leaf
                local = int(np.prod(l.sharding.shard_shape(l.shape)))
            else:
                local = l.size
            per_dev += local * itemsize
            n_shards = max(n_shards, l.size // max(local, 1))
        return {
            "kv_cache_bytes": int(sum(
                l.size * jnp.dtype(l.dtype).itemsize for l in leaves)),
            # per-device slice of the cache under the serving mesh (the
            # whole cache on a single device); n_kv_shards is the widest
            # shard factor across leaves — indivisible leaves replicate,
            # so per-device bytes may exceed global/n_kv_shards
            "kv_bytes_per_device": int(per_dev),
            "n_kv_shards": int(n_shards),
        }


class SlotManager(_SlotOccupancy):
    """Owns the contiguous multi-slot KV cache.

    The cache is ``models.transformer.init_cache(cfg, n_slots, max_seq)``
    — slot ``i`` is batch row ``i`` of every leaf.  Admission grafts a
    single-sequence prefix cache into a free slot; eviction just frees
    the slot id: stale keys/values beyond a new occupant's prefix are
    masked out by the per-slot ``kv_len`` until overwritten.
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, max_seq: int):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.cache = T.init_cache(cfg, n_slots, max_seq)
        self.states: List[Optional[_SlotState]] = [None] * n_slots
        self._graft = jax.jit(T.graft_slot_cache)
        self._template = None          # batch-1 cache, built on first snapshot
        self._extract = jax.jit(T.extract_slot_cache)

    # -- admission / eviction ----------------------------------------------
    def can_admit(self, req: Request) -> bool:
        return True                    # a free slot is the only resource

    def place(self, slot: int, prefix_cache, state: _SlotState) -> None:
        if self.states[slot] is not None:
            raise RuntimeError(f"slot {slot} occupied")
        self.cache = self._graft(self.cache, prefix_cache, jnp.int32(slot))
        self.states[slot] = state

    def evict(self, slot: int) -> None:
        self.states[slot] = None

    # -- preemption (snapshot / detach / restore) ---------------------------
    def snapshot(self, slot: int):
        """Host-side copy of slot ``slot``'s full cache row (the whole
        max_seq reservation, so restore needs no length bookkeeping)."""
        if self._template is None:
            self._template = T.init_cache(self.cfg, 1, self.max_seq)
        return jax.device_get(
            self._extract(self.cache, self._template, jnp.int32(slot)))

    def detach(self, slot: int, *, release_pages: bool = True) -> _SlotState:
        """Remove the slot's state without finishing it.  The contiguous
        row holds no pooled resource, so ``release_pages`` is a no-op."""
        st = self.states[slot]
        self.states[slot] = None
        return st

    def discard_detached(self, state: _SlotState) -> None:
        """Drop a detached sequence for good — no pooled resource to
        return in the contiguous layout."""

    def can_restore(self, state: _SlotState, spilled: bool) -> bool:
        return True

    def restore(self, slot: int, state: _SlotState, kv=None, *,
                spilled: bool = True) -> None:
        """Re-place a detached sequence; ``kv`` is a ``snapshot`` pytree
        (required here: the row may have been reused since detach)."""
        if self.states[slot] is not None:
            raise RuntimeError(f"slot {slot} occupied")
        if kv is None:
            raise RuntimeError("contiguous restore needs the KV snapshot")
        self.cache = self._graft(self.cache, jax.tree.map(jnp.asarray, kv),
                                 jnp.int32(slot))
        self.states[slot] = state

    def kv_cache_stats(self) -> dict:
        return {"kv_layout": "contiguous", **super().kv_cache_stats()}


@dataclass
class _PagedSlotState(_SlotState):
    pages: List[int] = field(default_factory=list)   # block table
    budget: int = 0                    # lifetime PRIVATE pages reserved
    #                                    (shared-attached pages cost no
    #                                    reservation — they are already
    #                                    in use elsewhere)
    synced_pages: int = 0              # leading pages bit-identical to the
    #                                    host spill store (KV-delta spills):
    #                                    decode writes lower the watermark,
    #                                    a spill/resume raises it
    shared_pages: int = 0              # leading pages attached by reference
    #                                    from the prefix index; a write into
    #                                    one forks a private copy first
    #                                    (copy-on-write) and lowers this


class PagedSlotManager(_SlotOccupancy):
    """Owns the paged KV pool and per-slot block tables.

    The cache is ``models.transformer.init_paged_cache(cfg, n_pages + 1,
    page_size)`` — page 0 is the scratch page inactive slots write to.
    Admission reserves a request's worst-case lifetime page count
    (``ceil((prompt + max_new - 1)/page_size)``) so neither prefill nor
    decode can ever stall mid-sequence, but allocates NO pages and
    copies NO cache: the sequence opens in the PREFILLING state and
    prompt chunks land directly in pages drawn chunk-by-chunk against
    the reservation (``grow_for_chunk``).  Decode grows the block table
    one page per ``page_size`` steps; eviction returns pages plus any
    unused reservation to the free list.  Stale KV in recycled pages
    beyond a slot's ``kv_len`` stays masked until overwritten — the
    same overwrite-before-read guarantee as the contiguous layout.
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, max_seq: int, *,
                 page_size: int = 16, pool_pages: Optional[int] = None,
                 prefix_cache: bool = False, mesh=None, logical_map=None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.page_size = page_size
        self.mesh = mesh
        if pool_pages is None:
            pool_pages = default_pool_pages(n_slots, max_seq, page_size)
        self.allocator = BlockAllocator(pool_pages)
        self.prefix_index = (PagePrefixIndex(self.allocator, page_size)
                             if prefix_cache else None)
        self.cow_copies = 0            # shared pages forked before a write
        self.prefill_positions_skipped = 0   # prompt positions attached by
        #                                      reference (never recomputed)
        self.max_bt = pages_for(max_seq, page_size)
        self.cache = T.init_paged_cache(cfg, pool_pages + 1, page_size)
        self.states: List[Optional[_PagedSlotState]] = [None] * n_slots
        if mesh is None:
            self._graft = jax.jit(T.graft_paged_cache)
            self._copy = jax.jit(T.copy_paged_pages)
        else:
            # place the pool: KV heads (MLA latent rank) over "model",
            # the layer/page/offset axes whole on every device — so the
            # extract gather below still device_gets a token-exact global
            # snapshot and graft scatters host pages back under GSPMD
            pool_sh = paged_cache_pspecs(mesh, cfg, self.cache, logical_map)
            self.cache = jax.device_put(self.cache, pool_sh)
            # pin the output sharding of every pool-rewriting callable:
            # scatter sharding inference CAN keep the operand layout, but
            # pinning it makes resharding impossible rather than unlikely
            self._graft = jax.jit(T.graft_paged_cache, out_shardings=pool_sh)
            self._copy = jax.jit(T.copy_paged_pages, out_shardings=pool_sh)
        self._extract = jax.jit(T.extract_paged_cache)

    def _lifetime_pages(self, req: Request) -> int:
        return req.pages_needed(self.page_size)

    def _prefix_plan(self, req: Request):
        """(cached page ids to attach, resume position, private page
        budget) for admitting ``req``.  Attaches the longest indexed
        run of the prompt's leading FULL pages; prefill then resumes at
        the first uncovered position and is charged only for what it
        actually runs.  A fully covered prompt still re-runs its final
        position — the first emitted token needs that position's logits
        — which copy-on-writes the last shared page, budgeted as one
        extra private page."""
        lifetime = self._lifetime_pages(req)
        if self.prefix_index is None or req.prefill_pos:
            return [], req.prefill_pos, lifetime
        prompt = req.prompt
        pages = self.prefix_index.match(prompt)
        k = min(len(pages), len(prompt) // self.page_size)
        pages = pages[:k]
        if k and k * self.page_size == len(prompt):
            return pages, len(prompt) - 1, lifetime - k + 1
        return pages, k * self.page_size, lifetime - k

    # -- admission / eviction ----------------------------------------------
    def can_admit(self, req: Request) -> bool:
        _, _, budget = self._prefix_plan(req)
        if self.allocator.can_reserve(budget):
            return True
        # index-only pages (refcount 1) are reclaimable: admission may
        # evict cached prefixes rather than block behind them
        return (self.prefix_index is not None
                and self.allocator.available()
                + self.prefix_index.reclaimable() >= budget)

    def fits_pool(self, req: Request) -> bool:
        """Whether the request could EVER be admitted (pool capacity)."""
        return self._lifetime_pages(req) <= self.allocator.n_pages

    def place_prefilling(self, slot: int, req: Request, clock: int) -> None:
        """Open ``slot`` in the PREFILLING state: reserve the request's
        worst-case lifetime budget of PRIVATE pages (admission control
        is unchanged when nothing is shared) but allocate nothing —
        prompt chunks allocate their pages as they land
        (``grow_for_chunk``), and no prefix cache is ever grafted.
        With a prefix index, cache-hit pages attach by reference: the
        model work for the covered positions is skipped outright
        (``Request.prefill_pos`` opens past them, so the unified step
        charges 0 prefill tokens for them) and the shared pages cost no
        reservation."""
        if self.states[slot] is not None:
            raise RuntimeError(f"slot {slot} occupied")
        pages, resume, budget = self._prefix_plan(req)
        if not self.allocator.can_reserve(budget) and self.prefix_index:
            self.prefix_index.evict(budget - self.allocator.available())
        self.allocator.reserve(budget)
        self.allocator.share(pages)
        if self.prefix_index is not None and not req.prefill_pos:
            self.prefix_index.note_attach(len(pages))
        if pages:
            self.prefill_positions_skipped += resume
        req.prefill_pos = resume
        self.states[slot] = _PagedSlotState(
            request=req, pos=resume, next_tok=0,
            admitted_step=clock, phase=PREFILLING, pages=list(pages),
            budget=budget, synced_pages=len(pages),
            shared_pages=len(pages))

    def _fork_shared(self, slot: int, first_write: int) -> None:
        """Copy-on-write: before ``slot`` writes into page
        ``first_write``, give it private copies of every shared page
        from there on (in practice only the last shared page, when a
        fully covered prompt re-runs its final position).  A page still
        referenced elsewhere is duplicated device-side
        (``copy_paged_pages``) into a page drawn from the slot's own
        reservation and this sequence's reference on the original is
        dropped; a page nobody else holds any more is simply
        reclassified as private."""
        st = self.states[slot]
        if first_write >= st.shared_pages:
            return
        for d in range(first_write, st.shared_pages):
            old = st.pages[d]
            if self.allocator.refcount(old) > 1:
                new = self.allocator.alloc(1)[0]
                self.cache = self._copy(self.cache,
                                        jnp.asarray([old], jnp.int32),
                                        jnp.asarray([new], jnp.int32))
                st.pages[d] = new
                self.allocator.release([old])
                self.cow_copies += 1
        st.shared_pages = first_write
        st.synced_pages = min(st.synced_pages, first_write)

    def grow_for_chunk(self, slot: int, n_positions: int) -> None:
        """Allocate pages (against the admission reservation) so the
        slot's block table covers prompt positions [0, n_positions),
        forking any shared page the chunk would write into
        (copy-on-write), and lower the ``synced_pages`` watermark to
        the first page this chunk writes — those pages now diverge from
        any host spill copy."""
        st = self.states[slot]
        first_write = st.pos // self.page_size
        self._fork_shared(slot, first_write)
        while len(st.pages) * self.page_size < n_positions:
            st.pages.extend(self.allocator.alloc(1))
        st.synced_pages = min(st.synced_pages, first_write)

    def note_prefill_complete(self, slot: int) -> None:
        """Register the sequence's IMMUTABLE prompt pages (fully covered
        by the prompt — decode never writes into them) in the prefix
        index, so later requests sharing the prefix attach them by
        reference instead of recomputing."""
        if self.prefix_index is None:
            return
        st = self.states[slot]
        prompt = st.request.prompt
        self.prefix_index.insert(prompt,
                                 st.pages[:len(prompt) // self.page_size])

    def evict(self, slot: int) -> None:
        st = self.states[slot]
        n_private = len(st.pages) - st.shared_pages
        self.allocator.release(st.pages,
                               unreserve=st.budget - n_private)
        self.states[slot] = None

    # -- preemption (snapshot / detach / restore) ---------------------------
    def snapshot(self, slot: int, since: int = 0):
        """Host-side copy of the slot's live pages as a prefix-shaped
        pytree (leaves (L, 1, n_pages * page_size, ...)) — the
        ``extract_paged_cache`` inverse of the admission graft, so
        restore round-trips bit-exactly through ``graft_paged_cache``.
        ``since`` skips the first ``since`` (clean) pages — the KV-delta
        spill path, which ships only pages dirtied since the last spill.
        Returns None when there is nothing newer than ``since``.  The
        slice happens host-side so the jitted gather is keyed only on
        the delta's page count, not on (table length, since) pairs."""
        st = self.states[slot]
        if since >= len(st.pages):
            return None
        return jax.device_get(
            self._extract(self.cache,
                          jnp.asarray(st.pages[since:], jnp.int32)))

    def snapshot_state(self, state: _PagedSlotState):
        """Host-side copy of a DETACHED-but-resident sequence's pages
        (a resident swap entry at checkpoint time — its pages are still
        committed in the pool but it owns no slot).  None when the
        sequence holds no pages yet."""
        if not state.pages:
            return None
        return jax.device_get(
            self._extract(self.cache, jnp.asarray(state.pages, jnp.int32)))

    def detach(self, slot: int, *, release_pages: bool) -> _PagedSlotState:
        """Remove the slot's state without finishing it.  With
        ``release_pages`` (spill preemption) the sequence's PRIVATE
        pages and its unused reservation go back to the pool —
        reclaimable by waiting requests — and the caller must hold a
        ``snapshot`` of them; shared-prefix pages keep this sequence's
        reference (they are pinned in the pool, never spilled, and cost
        nothing to re-attach at resume).  Without (resident preemption)
        everything stays committed and restore is free."""
        st = self.states[slot]
        self.states[slot] = None
        if release_pages:
            private = st.pages[st.shared_pages:]
            self.allocator.release(private,
                                   unreserve=st.budget - len(private))
            st.pages = st.pages[:st.shared_pages]
        return st

    def discard_detached(self, state: _PagedSlotState) -> None:
        """Drop a detached (spilled) sequence without resuming it — the
        redo-from-prefill path.  Releases the shared-prefix references
        the spill kept pinned; private pages and reservation were
        already returned at detach."""
        if state.pages:
            self.allocator.release(state.pages)
            state.pages = []
        state.shared_pages = 0
        state.synced_pages = 0

    def can_restore(self, state: _PagedSlotState, spilled: bool) -> bool:
        """Spilled sequences re-reserve their full lifetime budget, so a
        restore can never stall mid-decode once admitted — the same
        discipline as first admission."""
        return (not spilled) or self.allocator.can_reserve(state.budget)

    def restore(self, slot: int, state: _PagedSlotState, kv=None, *,
                spilled: bool = True) -> None:
        """Re-place a detached sequence.  ``spilled`` re-reserves the
        private lifetime budget (the detach released it); ``kv`` is the
        host snapshot of the PRIVATE pages, grafted into freshly
        allocated ones appended after the still-attached shared prefix
        — None for a resident swap, or for a sequence preempted before
        its first private page landed (nothing to restore: chunks
        redo)."""
        if self.states[slot] is not None:
            raise RuntimeError(f"slot {slot} occupied")
        if spilled:
            self.allocator.reserve(state.budget)
            if kv is not None:                 # realloc + graft back
                leaf = jax.tree.leaves(kv)[0]
                n = leaf.shape[2] // self.page_size
                new = self.allocator.alloc(n)
                state.pages.extend(new)
                self.cache = self._graft(self.cache,
                                         jax.tree.map(jnp.asarray, kv),
                                         jnp.asarray(new, jnp.int32))
        self.states[slot] = state

    # -- paged decode plumbing ---------------------------------------------
    def ensure_write_pages(self, skip=()) -> None:
        """Grow each active slot's block table to cover its next write
        position.  Draws on the reservation made at admission, so it
        cannot fail mid-sequence.  Also lowers the slot's ``synced_pages``
        watermark to the page this tick writes into — that page now
        diverges from any host spill copy, so the next spill must ship
        it again (everything below the watermark stays delta-exempt).
        PREFILLING slots — and ``skip`` slots, whose verify pass grew
        its own pages through ``grow_for_chunk`` — are skipped: their
        pages grow chunk-by-chunk.  A write landing in a shared page
        forks a private copy first (copy-on-write) — no decode write
        ever touches a page another holder can read."""
        for slot, st in enumerate(self.states):
            if st is None or st.phase != DECODING or slot in skip:
                continue
            self._fork_shared(slot, st.pos // self.page_size)
            while len(st.pages) <= st.pos // self.page_size:
                st.pages.extend(self.allocator.alloc(1))
            st.synced_pages = min(st.synced_pages, st.pos // self.page_size)

    def block_tables(self, skip=()) -> np.ndarray:
        """(n_slots, max_bt) int32 page ids for the DECODE sub-batch;
        unused entries — and whole rows of inactive, PREFILLING or
        ``skip`` slots, whose dummy decode write must not touch their
        real pages — point at the scratch page 0."""
        bt = np.zeros((self.n_slots, self.max_bt), np.int32)
        for i, st in enumerate(self.states):
            if st is not None and st.phase == DECODING and i not in skip:
                bt[i, :len(st.pages)] = st.pages
        return bt

    def chunk_block_table(self, slot: int) -> np.ndarray:
        """(1, max_bt) int32 — the single-sequence block table a prefill
        chunk writes through (unused entries at the scratch page)."""
        bt = np.zeros((1, self.max_bt), np.int32)
        pages = self.states[slot].pages
        bt[0, :len(pages)] = pages
        return bt

    def kv_cache_stats(self) -> dict:
        a = self.allocator
        base = super().kv_cache_stats()
        return {
            "kv_layout": "paged",
            "page_size": self.page_size,
            "pool_pages": a.n_pages,
            "peak_pages_in_use": a.peak_in_use,
            "peak_pages_committed": a.peak_committed,
            "page_pool_utilization": round(a.utilization(), 4),
            "cow_page_copies": self.cow_copies,
            "prefill_positions_skipped": self.prefill_positions_skipped,
            **(self.prefix_index.stats()
               if self.prefix_index is not None else {}),
            **base,
            # per-device ledger view: the page axes are never sharded, so
            # every device's allocator state IS the global ledger
            **per_device_pool_stats(
                a, n_shards=base["n_kv_shards"],
                kv_bytes_per_device=base["kv_bytes_per_device"]),
        }


class ContinuousEngine:
    """Continuous-batching greedy decoding under one unified
    token-budget step.

    Supported families: dense / moe (incl. MLA) / hybrid / ssm.  vlm and
    audio need per-request side inputs (patch embeds, encoder frames)
    and are served by the fixed-slot engine.

    Paged families (dense/moe) admit through CHUNKED prefill: an
    admitted sequence opens in the PREFILLING state and every tick
    spends up to ``prefill_budget_tokens`` prompt tokens across the
    PREFILLING slots (FIFO by admission, at most one chunk per slot per
    tick), written straight into incrementally allocated KV pages by
    ``models.transformer.prefill_chunk`` — no whole-prompt forward, no
    prefix-cache graft.  Chunk shapes are bucketed (next power of two,
    floor 8, capped at max_seq) so the jitted chunk step hits a handful
    of compiled shapes; pad positions write to the scratch page and are
    masked out.  The budget counts REAL prompt tokens — the executed
    width is the bucket, so each chunk may round up to the floor/next
    power of two; with a power-of-two budget >= 8 (the default) a
    chunk's width never exceeds the budget itself.
    ``prefill_budget_tokens=None`` removes the bound (each prompt lands
    as one chunk — the monolithic comparator the benchmark gates
    against).  Recurrent families (hybrid/ssm, always contiguous)
    prefill monolithically at the exact prompt length — their prefix
    state integrates every input position, so chunking or padding would
    change it.

    kv_layout: "paged" (default for dense/moe via "auto") pools KV in
    fixed-size pages with per-sequence block tables — admission then
    blocks on page-pool exhaustion instead of slot count; "contiguous"
    reserves a full max_seq row per slot (always used for the
    fixed-size recurrent state of hybrid/ssm).  page_size / pool_pages
    are the paged pool's sizing knobs (pool_pages defaults to 75% of
    the contiguous layout's positions; see ``paging.default_pool_pages``).

    prefix_cache=True (paged only) turns on prefix sharing: a
    ``paging.PagePrefixIndex`` keeps finished prompts' immutable full
    pages alive in the pool, admission attaches matching leading pages
    by REFERENCE (refcounted — shared pages are not double-budgeted)
    and skips the model work for the covered positions entirely (they
    charge 0 tokens against the unified step's prefill budget; a fully
    covered prompt still re-runs its final position for the first
    token's logits, copy-on-write-forking the last shared page).
    Token-exact with prefix_cache=False: cached pages hold exactly the
    KV the skipped chunks would have recomputed.

    Speculative draft verification (paged layouts): a DECODING slot
    holding pending draft tokens (attached via ``attach_drafts`` or a
    ``Request.draft_toks`` stream) verifies up to ``draft_k`` of them
    in ONE ``prefill_chunk`` pass instead of taking that tick's decode
    step — the chunk runs ``[next_tok, d_1..d_k]`` at the slot's
    current position, the per-position argmaxes give the longest
    agreeing draft prefix, and the first disagreeing position's argmax
    is the correction token, so the emitted stream is token-for-token
    identical to plain greedy decode whatever the drafts were.
    Rejected draft positions leave stale KV beyond the slot's
    ``kv_len``, which the same masking that recycles pages already
    hides — rollback is free.

    ``last_tick_prefill_tokens`` / ``last_tick_decode_tokens`` /
    ``last_tick_verify_tokens`` expose the unified step's per-tick
    token accounting (prefill tokens spent; decoding slots stepped;
    draft+input tokens verified) — the benchmark and the property
    suite gate ``prefill <= budget`` and ``decode <= n_slots`` on
    them (verify adds at most ``n_slots * (draft_k + 1)``).
    """

    FAMILIES = ("dense", "moe", "hybrid", "ssm")
    PAGED_FAMILIES = ("dense", "moe")

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 4,
                 max_seq: int = 2048, queue_capacity: Optional[int] = None,
                 kv_layout: str = "auto", page_size: int = 16,
                 pool_pages: Optional[int] = None,
                 prefill_budget_tokens: Optional[int] = 64,
                 prefix_cache: bool = False, draft_k: int = 8,
                 mesh=None, logical_map=None):
        if cfg.family not in self.FAMILIES:
            raise NotImplementedError(
                f"ContinuousEngine does not serve family {cfg.family!r}")
        if kv_layout not in ("auto", "paged", "contiguous"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        if draft_k < 1:
            raise ValueError("draft_k must be >= 1 (max draft tokens "
                             "verified per slot per tick)")
        if kv_layout == "auto":
            kv_layout = ("paged" if cfg.family in self.PAGED_FAMILIES
                         else "contiguous")
        if prefill_budget_tokens is not None and prefill_budget_tokens < 1:
            raise ValueError("prefill_budget_tokens must be >= 1 (or None "
                             "for an unbounded, monolithic-style tick)")
        if prefix_cache and kv_layout != "paged":
            raise ValueError("prefix_cache needs the paged KV layout "
                             "(sharing is page-granular)")
        if mesh is not None and kv_layout != "paged":
            raise ValueError("mesh serving shards the paged KV pool — "
                             "contiguous/recurrent layouts are unsharded")
        self.cfg = cfg
        self.mesh = mesh
        self.logical_map = (dict(logical_map or SERVING_LOGICAL_MAP)
                            if mesh is not None else None)
        mkey = _mesh_fingerprint(mesh)
        if mesh is not None:
            # tensor-parallel placement: attention/FFN weights split over
            # "model", experts expert-parallel, everything else replicated
            params = jax.device_put(
                params, params_pspecs(mesh, params, self.logical_map))
        self.params = params
        self.max_seq = max_seq
        self.kv_layout = kv_layout
        self.prefill_budget_tokens = prefill_budget_tokens
        wrap = lambda fn: _mesh_wrap(mesh, self.logical_map, fn)  # noqa: E731
        if kv_layout == "paged":
            self.slots = PagedSlotManager(cfg, n_slots, max_seq,
                                          page_size=page_size,
                                          pool_pages=pool_pages,
                                          prefix_cache=prefix_cache,
                                          mesh=mesh,
                                          logical_map=self.logical_map)
            self._decode = _cached_jit(
                ("cont_decode_paged", cfg, mkey), lambda: wrap(jax.jit(
                    lambda p, c, t, pos, bt: T.decode_step(
                        p, cfg, c, t, pos, block_tables=bt))))
            self._chunk = _cached_jit(
                ("prefill_chunk", cfg, mkey), lambda: wrap(jax.jit(
                    lambda p, c, t, nv, off, bt, cap: T.prefill_chunk(
                        p, cfg, c, t, nv, off, bt, moe_capacity=cap),
                    static_argnums=(6,))))
        else:
            self.slots = SlotManager(cfg, n_slots, max_seq)
            self._decode = _cached_jit(
                ("cont_decode", cfg, mkey), lambda: wrap(jax.jit(
                    lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos))))
        self.queue = RequestQueue(max_batch=n_slots,
                                  capacity=queue_capacity)
        self.draft_k = draft_k
        self.clock = 0                        # unified-step ticks
        self.finish_order: List[int] = []
        self.results: Dict[int, RequestResult] = {}
        self.last_tick_prefill_tokens = 0
        self.last_tick_decode_tokens = 0
        self.last_tick_verify_tokens = 0
        self.prefill_tokens_total = 0         # prompt tokens actually run
        #                                       (prefix-cache hits charge 0)
        self.spec_verify_passes = 0           # one-chunk draft verifications
        self.spec_drafted_total = 0           # draft tokens verified
        self.spec_accepted_total = 0          # draft tokens accepted
        self.spec_draft_streams_dropped = 0   # streams whose first draft
        #                                       disagreed with the prefill
        self._spent_this_tick = 0
        self._verify_this_tick = 0
        self._tick_budget_left = self._budget()
        self._prefill = _cached_jit(
            ("cont_prefill", cfg, mkey), lambda: wrap(jax.jit(
                lambda p, t, cap: T.forward(p, cfg, {"tokens": t},
                                            moe_drop_free=True,
                                            moe_capacity=cap,
                                            return_cache=True, remat=False),
                static_argnums=(2,))))

    def clone_fresh(self) -> "ContinuousEngine":
        """A new engine with the same config/params/layout knobs and
        EMPTY serving state — the reboot path: device KV, slots, queue
        and results do not survive a crash; only a host checkpoint does
        (``serving.scheduler.PreemptiveScheduler.restore``).  Jitted
        callables come from the module cache, so this is cheap."""
        kw = dict(n_slots=self.slots.n_slots, max_seq=self.max_seq,
                  queue_capacity=self.queue.capacity,
                  kv_layout=self.kv_layout,
                  prefill_budget_tokens=self.prefill_budget_tokens,
                  draft_k=self.draft_k,
                  mesh=self.mesh, logical_map=self.logical_map)
        if self.kv_layout == "paged":
            kw.update(page_size=self.slots.page_size,
                      pool_pages=self.slots.allocator.n_pages,
                      prefix_cache=self.slots.prefix_index is not None)
        return ContinuousEngine(self.cfg, self.params, **kw)

    def _budget(self):
        b = self.prefill_budget_tokens
        return float("inf") if b is None else b

    @classmethod
    def init(cls, cfg: ModelConfig, seed: int = 0, **kw):
        params = T.init_params(jax.random.PRNGKey(seed), cfg,
                               max_seq=kw.get("max_seq", 2048))
        return cls(cfg, params, **kw)

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request) -> int:
        if req.max_new < 1:
            raise ValueError(
                f"request {req.rid}: max_new must be >= 1 "
                "(the prefill always emits one token)")
        if len(req.prompt) + req.max_new > self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + max_new "
                f"{req.max_new} exceeds max_seq {self.max_seq}")
        if self.kv_layout == "paged" and not self.slots.fits_pool(req):
            raise ValueError(
                f"request {req.rid}: needs more KV pages than the whole "
                f"pool ({self.slots.allocator.n_pages} x "
                f"{self.slots.page_size}) — raise pool_pages")
        if req.draft_toks is not None:
            d = np.asarray(req.draft_toks)
            if d.ndim != 1:
                raise ValueError(
                    f"request {req.rid}: draft_toks must be 1-D token ids, "
                    f"got shape {d.shape}")
            req.draft_toks = d.astype(np.int32)
        return self.queue.submit(req)

    def _bucket_len(self, S: int) -> int:
        if self.cfg.family in ("hybrid", "ssm"):
            return S                          # recurrent state is length-exact
        b = 8
        while b < S:
            b *= 2
        return min(b, self.max_seq)

    def _run_prefill(self, toks: np.ndarray):
        """Drop-free prefill; MoE archs use the dynamic per-batch
        expert-capacity bound (``_dynamic_capacity_prefill``)."""
        toks = jnp.asarray(toks)
        if self.cfg.moe is None:
            logits, _, pcache = self._prefill(self.params, toks, None)
            return logits, pcache
        return _dynamic_capacity_prefill(
            lambda cap: self._prefill(self.params, toks, cap),
            self.cfg, int(toks.size))

    def _admit(self, req: Request, slot: int) -> None:
        """Place ``req`` into ``slot``.  Paged layouts open the slot in
        the PREFILLING state and immediately spend whatever remains of
        this tick's prefill budget on its first chunk(s); contiguous
        layouts (recurrent families and the memory baseline) keep the
        monolithic prefill + slot graft."""
        if self.kv_layout == "paged":
            self.slots.place_prefilling(slot, req, self.clock)
            self._pump_prefill(slot)
            return
        S = len(req.prompt)
        bucket = self._bucket_len(S)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :S] = req.prompt
        logits, pcache = self._run_prefill(toks)
        first = int(jnp.argmax(logits[0, S - 1]))
        st = _SlotState(request=req, pos=S, next_tok=first, emitted=[first],
                        admitted_step=self.clock,
                        first_token_step=self.clock,
                        last_logits=np.asarray(logits[0, S - 1], np.float32))
        self.slots.place(slot, pcache, st)
        if len(st.emitted) >= req.max_new:    # max_new == 1: done at prefill
            self._finish(slot)

    # -- chunked prefill (paged layout) -------------------------------------
    def _chunk_bucket(self, C: int) -> int:
        """Jit bucket for a chunk of C real tokens: next power of two
        (floor 8), clamped to max_seq like ``_bucket_len``.  With a
        power-of-two budget >= 8 (the deployment default) the executed
        width never exceeds the budget itself."""
        b = 8
        while b < C:
            b *= 2
        return min(b, self.max_seq)

    def _run_chunk(self, toks: np.ndarray, n_valid: int, pos_offset: int,
                   bt: np.ndarray):
        """One jitted chunk forward; MoE archs run the dynamic
        per-chunk expert-capacity doubling loop (token-exact with the
        unbounded drop-free path on success)."""
        args = (jnp.asarray(toks), jnp.int32(n_valid), jnp.int32(pos_offset),
                jnp.asarray(bt))
        if self.cfg.moe is None:
            logits, _, cache = self._chunk(self.params, self.slots.cache,
                                           *args, None)
            return logits, cache
        return _dynamic_capacity_prefill(
            lambda cap: self._chunk(self.params, self.slots.cache, *args, cap),
            self.cfg, int(toks.size))

    def _pump_prefill(self, slot: int) -> None:
        """Spend the tick's remaining prefill-token budget streaming
        prompt chunks of ``slot``'s PREFILLING sequence into its pages.
        When the last chunk lands the sequence emits its first token
        and flips to DECODING (joining this very tick's decode batch,
        or finishing outright when ``max_new == 1``)."""
        st = self.slots.states[slot]
        req = st.request
        S = len(req.prompt)
        while st.phase == PREFILLING and self._tick_budget_left > 0:
            off = req.prefill_pos
            C = int(min(self._tick_budget_left, S - off))
            Cb = self._chunk_bucket(C)
            toks = np.zeros((1, Cb), np.int32)
            toks[0, :C] = req.prompt[off:off + C]
            self.slots.grow_for_chunk(slot, off + C)
            logits, self.slots.cache = self._run_chunk(
                toks, C, off, self.slots.chunk_block_table(slot))
            req.prefill_pos = off + C
            st.pos = off + C
            self._tick_budget_left -= C
            self._spent_this_tick += C
            self.prefill_tokens_total += C
            if req.prefill_pos >= S:
                first = int(jnp.argmax(logits[0, C - 1]))
                st.phase = DECODING
                st.next_tok = first
                st.emitted = [first]
                st.first_token_step = self.clock
                st.last_logits = np.asarray(logits[0, C - 1], np.float32)
                self.slots.note_prefill_complete(slot)
                if len(st.emitted) >= req.max_new:
                    self._finish(slot)
                elif req.draft_toks is not None and len(req.draft_toks):
                    # a draft stream rides the request (the satellite
                    # tier's answer): its head must reproduce the
                    # prefill token or the whole stream is stale
                    if int(req.draft_toks[0]) == first:
                        self.attach_drafts(slot, req.draft_toks[1:])
                    else:
                        self.spec_draft_streams_dropped += 1

    # -- speculative draft verification (paged layout) ----------------------
    def attach_drafts(self, slot: int, draft_toks) -> int:
        """Queue draft tokens on a DECODING slot for one-pass
        verification by the unified step.  Clamped so drafts that could
        never be emitted (the slot needs one free position for the
        correction/bonus token) are dropped HERE, before any verify
        pass runs or any ledger meters them.  Returns the number
        actually queued (0 under the contiguous layout, which has no
        chunk machinery to verify through — plain decode proceeds)."""
        st = self.slots.states[slot]
        if st is None or st.phase != DECODING:
            raise RuntimeError(
                f"slot {slot}: drafts need a DECODING occupant")
        if self.kv_layout != "paged":
            return 0
        rem = st.request.max_new - len(st.emitted)
        take = max(0, min(len(draft_toks), rem - 1 - len(st.drafts)))
        st.drafts.extend(int(t) for t in draft_toks[:take])
        return take

    def _verify_slot(self, slot: int) -> bool:
        """Verify up to ``draft_k`` of the slot's pending draft tokens
        in ONE prefill-chunk pass: run ``[next_tok, d_1..d_k]`` at the
        slot's current position (their KV lands in pages drawn from the
        admission reservation, exactly like a prompt chunk), accept the
        longest prefix of drafts agreeing with the per-position
        argmaxes and emit the first disagreeing position's argmax as
        the correction (or bonus) token — token-for-token identical to
        ``n_ok + 1`` plain greedy decode steps.  KV written for
        rejected positions sits beyond the slot's new ``kv_len`` and is
        masked until overwritten, so no rollback copy is needed.
        Returns False when there is no room left to speculate (the
        drafts are dropped and plain decode emits the final token)."""
        st = self.slots.states[slot]
        req = st.request
        rem = req.max_new - len(st.emitted)
        k = min(len(st.drafts), self.draft_k, rem - 1)
        if k <= 0:
            st.drafts = []
            return False
        C = k + 1
        Cb = self._chunk_bucket(C)
        toks = np.zeros((1, Cb), np.int32)
        toks[0, 0] = st.next_tok
        toks[0, 1:C] = st.drafts[:k]
        self.slots.grow_for_chunk(slot, st.pos + C)
        logits, self.slots.cache = self._run_chunk(
            toks, C, st.pos, self.slots.chunk_block_table(slot))
        preds = np.asarray(jnp.argmax(logits[0, :C], -1))
        n_ok = 0
        while n_ok < k and int(preds[n_ok]) == st.drafts[n_ok]:
            n_ok += 1
        out = st.drafts[:n_ok] + [int(preds[n_ok])]
        rest = st.drafts[k:]
        # leftover drafts (stream longer than draft_k) survive only a
        # full acceptance whose bonus token matches their head — any
        # disagreement makes the rest of the stream stale
        st.drafts = (rest[1:] if n_ok == k and rest and rest[0] == out[-1]
                     else [])
        st.emitted.extend(out)
        st.pos += n_ok + 1
        st.next_tok = out[-1]
        self.spec_verify_passes += 1
        self.spec_drafted_total += k
        self.spec_accepted_total += n_ok
        self._verify_this_tick += C
        if len(st.emitted) >= req.max_new:
            st.last_logits = np.asarray(logits[0, n_ok], np.float32)
            self._finish(slot)
        return True

    def _verify_pending(self) -> set:
        """Run the draft-verify pass for every DECODING slot holding
        pending drafts; returns the slots that advanced (they sit out
        this tick's batched decode — their tokens already landed)."""
        verified = set()
        if self.kv_layout != "paged":
            return verified
        for slot in self.slots.decoding_slots():
            if self.slots.states[slot].drafts and self._verify_slot(slot):
                verified.add(slot)
        return verified

    def spec_stats(self) -> dict:
        """Speculative-verification counters (cumulative)."""
        return {"draft_k": self.draft_k,
                "verify_passes": self.spec_verify_passes,
                "drafted": self.spec_drafted_total,
                "accepted": self.spec_accepted_total,
                "draft_streams_dropped": self.spec_draft_streams_dropped}

    def _finish(self, slot: int) -> None:
        st = self.slots.states[slot]
        req = st.request
        self.results[req.rid] = RequestResult(
            rid=req.rid, tokens=np.asarray(st.emitted, np.int32),
            prompt_len=len(req.prompt), admitted_step=st.admitted_step,
            finished_step=self.clock, first_token_step=st.first_token_step,
            n_preemptions=st.n_preemptions,
            logits_last=st.last_logits)
        self.finish_order.append(req.rid)
        self.slots.evict(slot)

    # -- the serve loop ----------------------------------------------------
    def _admit_arrivals(self) -> None:
        """Admit arrived requests (FIFO) into free slots.  Paged layout:
        admission additionally blocks while the page pool cannot cover
        the head request's worst-case lifetime — eviction returns pages,
        so the head is admitted once enough earlier sequences finish."""
        for slot in self.slots.free_slots():
            req = self.queue.peek()
            if req is None or req.arrival_t > self.clock:
                break
            if not self.slots.can_admit(req):
                break                         # page pool exhausted: wait
            self._admit(self.queue.pop(), slot)

    def _prefilling_order(self) -> List[int]:
        """PREFILLING slots in admission order (FIFO, slot id ties)."""
        sl = self.slots
        return sorted(sl.prefilling_slots(),
                      key=lambda s: (sl.states[s].admitted_step, s))

    def _end_tick(self) -> None:
        """Close the tick's token accounting and open the next budget."""
        self.last_tick_prefill_tokens = self._spent_this_tick
        self.last_tick_verify_tokens = self._verify_this_tick
        self.clock += 1
        self._spent_this_tick = 0
        self._verify_this_tick = 0
        self._tick_budget_left = self._budget()

    def _idle_tick(self) -> None:
        """A clock tick with no compute (a contact pass holding the
        engine, or nothing to serve) — the prefill budget still resets,
        so the next tick starts with a full allowance."""
        self.last_tick_decode_tokens = 0
        self._end_tick()

    def _decode_batch(self, skip=frozenset()) -> None:
        """ONE batched decode step over every DECODING slot (PREFILLING
        and empty slots — and ``skip`` slots, already advanced by this
        tick's verify pass — ride along masked to the scratch region)
        and evict finished sequences."""
        decoding = [s for s in self.slots.decoding_slots() if s not in skip]
        self.last_tick_decode_tokens = len(decoding)
        if not decoding:
            return
        toks, pos = self.slots.decode_inputs(skip)
        if self.kv_layout == "paged":
            self.slots.ensure_write_pages(skip)
            logits, self.slots.cache = self._decode(
                self.params, self.slots.cache, jnp.asarray(toks),
                jnp.asarray(pos), jnp.asarray(self.slots.block_tables(skip)))
        else:
            logits, self.slots.cache = self._decode(
                self.params, self.slots.cache, jnp.asarray(toks),
                jnp.asarray(pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1))
        for slot in decoding:
            st = self.slots.states[slot]
            st.emitted.append(int(nxt[slot]))
            st.next_tok = int(nxt[slot])
            st.pos += 1
            if len(st.emitted) >= st.request.max_new:
                # fetch the final-step logits row only for sequences
                # finishing now (confidence routing); copying every step
                # would put a (n_slots, V) host transfer on the hot path
                st.last_logits = np.asarray(logits[slot, 0], np.float32)
                self._finish(slot)

    def _unified_step(self) -> None:
        """ONE unified token-budget tick: spend what remains of the
        tick's ``prefill_budget_tokens`` across PREFILLING slots (FIFO
        by admission — admission itself already draws on the same
        allowance), verify pending draft tokens (one chunk pass per
        drafted slot, up to ``draft_k + 1`` tokens each), then run one
        batched decode step over the remaining DECODING slots.  Total
        model work this tick is therefore bounded by
        ``prefill_budget_tokens + n_slots * (draft_k + 1)`` tokens,
        whatever arrives."""
        if not self.slots.any_active():
            self._idle_tick()                 # wait for arrivals
            return
        for slot in self._prefilling_order():
            if self._tick_budget_left <= 0:
                break
            self._pump_prefill(slot)
        verified = self._verify_pending()
        self._decode_batch(skip=verified)
        self._end_tick()

    def step(self) -> List[int]:
        """Admit arrived requests into free slots, run one unified
        token-budget step, evict finished sequences.  Returns the rids
        finished during this step.  (``serving.scheduler`` drives
        ``_admit_arrivals`` / ``_unified_step`` separately to interpose
        preemption.)"""
        before = len(self.finish_order)
        self._admit_arrivals()
        self._unified_step()
        return self.finish_order[before:]

    def run(self, requests: Optional[List[Request]] = None
            ) -> Dict[int, RequestResult]:
        """Drain: submit ``requests`` (sorted by arrival), then step until
        queue and slots are empty.  Returns rid -> RequestResult."""
        for r in sorted(requests or [], key=lambda r: r.arrival_t):
            self.submit(r)
        while len(self.queue) or self.slots.any_active():
            self.step()
        return self.results

    def mesh_stats(self) -> dict:
        """Mesh/sharding accounting: device count, per-axis sizes and
        the MoE expert-parallel split (experts_per_device is the
        per-device dispatch width of serving prefill — the whole expert
        set without a mesh or for dense archs' 0 experts)."""
        E = self.cfg.moe.n_experts if self.cfg.moe is not None else 0
        if self.mesh is None:
            return {"mesh_devices": 1, "mesh_axes": {},
                    "n_expert_shards": 1, "experts_per_device": E}
        with mesh_rules(self.mesh, self.logical_map):
            n_exp = shard_count("expert", E) if E else 1
        return {
            "mesh_devices": int(self.mesh.size),
            "mesh_axes": {str(a): int(self.mesh.shape[a])
                          for a in self.mesh.axis_names},
            "n_expert_shards": int(n_exp),
            "experts_per_device": E // n_exp if E else 0,
        }

    def kv_cache_stats(self) -> dict:
        """Cache-memory accounting: total cache bytes plus, for the
        paged layout, the page-pool sizing knobs, peak utilization and
        the per-device (mesh-sharded) slice of each; mesh/expert
        accounting rides along for the bench's sharded lane."""
        return {**self.slots.kv_cache_stats(), **self.mesh_stats()}
