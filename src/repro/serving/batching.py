"""Request batching for the two-tier serving deployment.

Fixed-slot batcher: requests queue up, get padded to a common prompt
length and dispatched as one batch — the onboard tier favors small
batches (latency/power bound), the ground tier large ones (throughput).
"""
from __future__ import annotations

import collections
import itertools
from dataclasses import dataclass, field
from typing import Deque, List, Optional

import numpy as np

_ids = itertools.count()


@dataclass
class Request:
    prompt: np.ndarray                    # (S,) int32
    max_new: int = 16
    rid: int = field(default_factory=lambda: next(_ids))
    arrival_t: float = 0.0


@dataclass
class Batch:
    requests: List[Request]
    tokens: np.ndarray                    # (B, S_max) left-padded
    lengths: np.ndarray                   # (B,)


class RequestQueue:
    def __init__(self, max_batch: int = 8, pad_id: int = 0):
        self.max_batch = max_batch
        self.pad_id = pad_id
        self._q: Deque[Request] = collections.deque()

    def submit(self, req: Request) -> int:
        self._q.append(req)
        return req.rid

    def __len__(self) -> int:
        return len(self._q)

    def next_batch(self) -> Optional[Batch]:
        if not self._q:
            return None
        reqs = [self._q.popleft()
                for _ in range(min(self.max_batch, len(self._q)))]
        S = max(len(r.prompt) for r in reqs)
        toks = np.full((len(reqs), S), self.pad_id, np.int32)
        lens = np.empty((len(reqs),), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt   # left padding
            lens[i] = len(r.prompt)
        return Batch(requests=reqs, tokens=toks, lengths=lens)
