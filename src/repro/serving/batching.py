"""Request batching for the two-tier serving deployment.

Two admission disciplines feed the engines in ``serving.engine``:

  * Fixed-slot (seed behavior): requests queue up, get padded to a
    common prompt length and dispatched as one batch — the batch must
    drain before the next one starts.
  * Continuous (``ContinuousEngine``): the queue is drained one request
    at a time into whichever KV-cache slot frees up, so arrivals join
    mid-flight.  ``RequestQueue`` stays the single admission point; a
    bounded ``capacity`` gives the ground tier backpressure under the
    heavy-traffic regime instead of unbounded memory growth.  Under the
    paged KV layout admission is additionally gated on the page pool:
    ``Request.pages_needed`` is the worst-case lifetime page count the
    engine reserves up front.
"""
from __future__ import annotations

import collections
import itertools
from dataclasses import dataclass, field
from typing import Deque, List, Optional

import numpy as np

_ids = itertools.count()


def ensure_rid_floor(n: int) -> None:
    """Advance the global rid counter to at least ``n``.  A checkpoint
    restore rebuilds Requests with their ORIGINAL rids; without bumping
    the counter past them, the next fresh Request (e.g. an escalation
    ``clone``) could collide with a restored rid and cross-wire two
    sequences' results."""
    global _ids
    nxt = next(_ids)
    _ids = itertools.count(max(nxt, n))


class QueueFull(RuntimeError):
    """Raised when a bounded RequestQueue rejects a submission."""


@dataclass
class Request:
    prompt: np.ndarray                    # (S,) int32
    max_new: int = 16
    rid: int = field(default_factory=lambda: next(_ids))
    arrival_t: float = 0.0                # engine-clock steps
    priority: int = 0                     # higher preempts lower (scheduler)
    prefill_pos: int = 0                  # prompt tokens already chunked
    #                                       into the KV cache (the unified
    #                                       token-budget step admits prompts
    #                                       chunk-by-chunk; preempt/resume
    #                                       continues from here, and a
    #                                       redo-from-prefill resets it)
    draft_toks: Optional[np.ndarray] = None
    #                                       (n,) int32 speculative draft of
    #                                       the greedy continuation (e.g. the
    #                                       satellite tier's answer riding a
    #                                       ground escalation): the engine
    #                                       verifies it in chunked passes
    #                                       instead of decoding token-by-token

    def pages_needed(self, page_size: int) -> int:
        """Worst-case KV pages over the request's lifetime: the cache
        holds positions [0, prompt + max_new - 1) (the final emitted
        token is never written back)."""
        n_positions = len(self.prompt) + self.max_new - 1
        return -(-n_positions // page_size)

    def clone(self) -> "Request":
        """Fresh-rid copy for replaying the same workload through
        another engine (benchmark/test A-B comparisons); prefill
        progress and any attached draft stream do not carry over —
        drafts are delivery metadata the sender re-attaches."""
        return Request(prompt=self.prompt.copy(), max_new=self.max_new,
                       arrival_t=self.arrival_t, priority=self.priority)


@dataclass
class Batch:
    requests: List[Request]
    tokens: np.ndarray                    # (B, S_max) left-padded
    lengths: np.ndarray                   # (B,)


class RequestQueue:
    def __init__(self, max_batch: int = 8, pad_id: int = 0,
                 capacity: Optional[int] = None):
        self.max_batch = max_batch
        self.pad_id = pad_id
        self.capacity = capacity
        self._q: Deque[Request] = collections.deque()

    def submit(self, req: Request) -> int:
        if self.capacity is not None and len(self._q) >= self.capacity:
            raise QueueFull(
                f"queue at capacity ({self.capacity}); request {req.rid} "
                "rejected — retry after the engine drains")
        self._q.append(req)
        return req.rid

    def __len__(self) -> int:
        return len(self._q)

    def peek(self) -> Optional[Request]:
        return self._q[0] if self._q else None

    def pop(self) -> Request:
        return self._q.popleft()

    def arrived(self, now: float) -> List[Request]:
        """Queued requests whose arrival time has passed, FIFO order."""
        return [r for r in self._q if r.arrival_t <= now]

    def items(self) -> List[Request]:
        """The whole backlog in FIFO order (checkpoint serialization),
        including requests whose arrival time has not passed yet."""
        return list(self._q)

    def take(self, req: Request) -> Request:
        """Remove ``req`` (matched by identity: dataclass equality would
        compare the numpy prompts) from anywhere in the queue."""
        for i, r in enumerate(self._q):
            if r is req:
                del self._q[i]
                return req
        raise ValueError(f"request {req.rid} not queued")

    def requeue_front(self, req: Request) -> None:
        """Put an already-admitted request back at the head (abort /
        redo-from-prefill — any partial-prefill progress is discarded
        with the KV that held it); deliberately exempt from the capacity
        check — the request's slot was already granted once."""
        req.prefill_pos = 0
        self._q.appendleft(req)

    def next_batch(self) -> Optional[Batch]:
        if not self._q:
            return None
        reqs = [self._q.popleft()
                for _ in range(min(self.max_batch, len(self._q)))]
        S = max(len(r.prompt) for r in reqs)
        toks = np.full((len(reqs), S), self.pad_id, np.int32)
        lens = np.empty((len(reqs),), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt   # left padding
            lens[i] = len(r.prompt)
        return Batch(requests=reqs, tokens=toks, lengths=lens)


def poisson_trace(n_requests: int, *, rate: float = 0.5,
                  prompt_lens=(4, 16), max_new=(2, 24),
                  vocab_size: int = 256, seed: int = 0,
                  priorities=(0, 0)) -> List[Request]:
    """A Poisson arrival trace with heterogeneous prompt lengths and
    decode budgets — the workload continuous batching is built for.

    rate: mean arrivals per engine decode step; inter-arrival gaps are
    exponential.  prompt_lens / max_new / priorities: inclusive
    (lo, hi) ranges sampled uniformly (priorities defaults to all-0 —
    FIFO, no preemption pressure).  Returns requests sorted by
    arrival_t.
    """
    rng = np.random.default_rng(seed)
    sample_prio = tuple(priorities) != (0, 0)
    t = 0.0
    out = []
    for _ in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        S = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        out.append(Request(
            prompt=rng.integers(1, vocab_size, S).astype(np.int32),
            max_new=int(rng.integers(max_new[0], max_new[1] + 1)),
            arrival_t=t,
            # drawn only when asked: the default trace's RNG stream (and
            # therefore every seeded benchmark workload) stays identical
            priority=(int(rng.integers(priorities[0], priorities[1] + 1))
                      if sample_prio else 0)))
    return out
