"""Contact-window preemptive scheduling on the continuous engine.

The paper's setting (§II): onboard compute must yield to downlink work
whenever a ground-station pass opens, and the downlink is only available
during those passes.  PR 2's page-reservation design makes yielding
cheap — a live sequence is just (slot state, block table, KV pages) —
so this module adds:

  * ``PreemptiveScheduler`` — a priority scheduler over ONE
    ``ContinuousEngine``.  ``preempt(slot)`` snapshots the slot's state
    plus block table into a swap ledger and evicts the slot; the KV
    either stays resident (pages remain committed in the device pool)
    or spills to a host-side store (``extract_paged_cache`` snapshot,
    pages released — reclaimable by waiting requests).  ``resume()``
    re-places the sequence token-exactly: a spilled snapshot is grafted
    back through ``graft_paged_cache`` into freshly allocated pages, a
    whole number of pages so the round trip is bit-exact.  Higher
    ``Request.priority`` arrivals may preempt lower-priority active
    sequences; swapped sequences resume highest-priority-first, so
    every admitted request eventually finishes.
  * ``SpaceGroundScheduler`` — drives a (satellite, ground) engine pair
    (``configs/tiansuan_pair``) against ``ContactSchedule`` windows.
    Each pass is an *overlapped pipeline* (``overlap=True``, default):
    a ``core.link.TransmitLane`` drains the downlink backlog against
    the pass's per-tick byte budget — finished results compact,
    low-confidence sequences escalated raw to the ground tier via the
    ``ConfidenceGate`` from ``core/cascade``'s deployment — while
    satellite decode CONTINUES through the pass; only the transmit
    lane's staging reserve (``comm_reserve_pages`` held via
    ``hold_pages``) can spill sequences.  ``overlap=False`` preempts
    all decode for each whole pass (the stop-the-world schedule).  An
    ``EnergyModel`` ledger accounts compute vs comm joules.

Re-preempting a long sequence ships only a KV *delta*: the host-side
``serving.paging.DeltaSpillStore`` keeps spilled snapshots across
resumes, the block table tracks a ``synced_pages`` watermark, and
``extract_paged_cache(..., since=...)`` gathers just the pages dirtied
since the last spill — base + delta reassemble token-exactly.
``spill_codec="zstd"`` keeps the host entries compressed, and
``spill_max_entries``/``spill_max_bytes`` LRU-cap the store: a
long-idle swapped sequence whose record is evicted is requeued and
redone from prefill.

Every engine tick under these schedulers is a *unified token-budget
step* (``engine.prefill_budget_tokens``): arriving prompts stream in
as bounded chunks next to in-flight decodes, so a pass's transmit lane
always gets its next tick within a bounded latency — and mid-PREFILL
sequences preempt/resume through the same swap ledger (their chunk
progress rides ``Request.prefill_pos`` and the ``synced_pages``
watermark).

Both schedulers are deterministic: same trace + same windows => same
tokens, preemption points, and ledger.
"""
from __future__ import annotations

import os
import tempfile
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.store import load_checkpoint_raw, save_checkpoint
from repro.core.energy import EnergyModel
from repro.core.faults import FaultInjector
from repro.core.gating import ConfidenceGate
from repro.core.link import ContactSchedule, TransmitLane, \
    payload_bytes_draft, payload_bytes_raw, payload_bytes_result
from repro.core.telemetry import Ledger
from repro.serving.batching import Request, ensure_rid_floor
from repro.serving.engine import ContinuousEngine, RequestResult, \
    _PagedSlotState, _SlotState
from repro.serving.paging import DeltaSpillStore, SpillCorruption


@dataclass
class SwapEntry:
    """One preempted sequence in the swap ledger."""
    state: object                       # the engine's detached _SlotState
    kv: Optional[dict]                  # host KV snapshot; None when the
    #                                     swap is resident, when the spill
    #                                     lives in the DeltaSpillStore (the
    #                                     store's record is the ONLY host
    #                                     copy), or when a PREFILLING
    #                                     sequence had no pages yet
    preempted_step: int                 # engine clock at preemption
    spilled: bool = True                # pages released (resume re-reserves)

    @property
    def rid(self) -> int:
        return self.state.request.rid

    @property
    def priority(self) -> int:
        return self.state.request.priority


class PreemptiveScheduler:
    """Preempt-and-resume scheduling over one ``ContinuousEngine``.

    preempt_mode: "spill" (default) releases the sequence's pages to
    the pool so waiting requests can claim them; "resident" keeps pages
    committed for a zero-copy resume (right when the pool is
    uncontended and the pause is short).  Either way resume is
    token-exact — the resident path never moves KV, the spill path
    round-trips whole pages through ``extract_paged_cache`` /
    ``graft_paged_cache`` (contiguous layout: the full cache row).
    """

    def __init__(self, engine: ContinuousEngine, *,
                 preempt_mode: str = "spill", delta_spill: bool = True,
                 spill_codec: Optional[str] = None,
                 spill_max_entries: Optional[int] = None,
                 spill_max_bytes: Optional[int] = None,
                 fault_injector: Optional[FaultInjector] = None):
        if preempt_mode not in ("spill", "resident"):
            raise ValueError(f"unknown preempt_mode {preempt_mode!r}")
        self.engine = engine
        self.preempt_mode = preempt_mode
        # KV-delta spills (paged layout only): the host store keeps each
        # spilled sequence's snapshot across resumes, so a re-preemption
        # ships only the pages dirtied since — the block table's
        # ``synced_pages`` watermark — instead of the whole live set.
        # spill_codec="zstd" compresses host entries (optional dep);
        # spill_max_entries/_bytes cap the store with LRU eviction —
        # an evicted, still-swapped sequence redoes from prefill.
        self.store: Optional[DeltaSpillStore] = (
            DeltaSpillStore(engine.slots.page_size, codec=spill_codec,
                            max_entries=spill_max_entries,
                            max_bytes=spill_max_bytes,
                            injector=fault_injector)
            if delta_spill and hasattr(engine.slots, "allocator") else None)
        self.held_pages = 0             # transmit-lane page hold (overlap)
        self.swapped: Dict[int, SwapEntry] = {}      # rid -> entry
        self.n_preemptions = 0
        self.n_spills = 0
        self.n_resumes = 0
        self.n_redo_from_prefill = 0    # swap entries lost to store eviction
        self.n_redo_from_corruption = 0  # swap entries lost to a failed
        #                                  spill-record checksum
        self.swapped_steps = 0          # total clock ticks spent swapped out
        self.resume_s: List[float] = [] # wall seconds per restore

    # -- delegation ---------------------------------------------------------
    @property
    def clock(self) -> int:
        return self.engine.clock

    @property
    def results(self) -> Dict[int, RequestResult]:
        return self.engine.results

    def submit(self, req: Request) -> int:
        return self.engine.submit(req)

    def has_work(self) -> bool:
        return bool(len(self.engine.queue) or self.engine.slots.any_active()
                    or self.swapped)

    # -- preemption ---------------------------------------------------------
    def preempt(self, slot: int, mode: Optional[str] = None) -> int:
        """Swap the sequence in ``slot`` out; returns its rid.  The slot
        is free afterwards, and under "spill" its KV pages are back in
        the pool for waiting requests."""
        mode = mode or self.preempt_mode
        slots = self.engine.slots
        if not hasattr(slots, "allocator"):
            mode = "spill"       # contiguous rows have no resident identity:
            #                      the slot may be regrafted while swapped
        st0 = slots.states[slot]
        if st0 is None:
            raise RuntimeError(f"preempt of empty slot {slot}")
        kv = None
        if mode == "spill":
            shared = getattr(st0, "shared_pages", 0)
            if not hasattr(slots, "allocator"):
                kv = slots.snapshot(slot)          # contiguous: full row
            elif len(st0.pages) > shared:
                # shared-prefix pages stay pinned in the pool (the swap
                # entry keeps its refs), so only the private tail is
                # spilled — store records live in PRIVATE page
                # coordinates (page 0 of a record == first page past
                # the shared prefix)
                if self.store is not None:
                    # the store's record IS the host copy — the swap
                    # entry carries no duplicate snapshot, so the
                    # codec/caps really bound host spill memory
                    synced = max(st0.synced_pages, shared)
                    delta = slots.snapshot(slot, since=synced)
                    try:
                        self.store.merge(st0.request.rid, delta,
                                         synced - shared,
                                         len(st0.pages) - shared)
                    except SpillCorruption:
                        # the base record failed its checksum (the store
                        # discarded it) — but every live page is still
                        # on device, so re-ship the FULL private set as
                        # a fresh record instead of grafting garbage
                        full = slots.snapshot(slot, since=shared)
                        self.store.merge(st0.request.rid, full, 0,
                                         len(st0.pages) - shared)
                else:
                    kv = slots.snapshot(slot, since=shared)
            # else: PREFILLING with no chunk landed yet — nothing to
            # snapshot; the re-placed state redoes its chunks on resume
        st = slots.detach(slot, release_pages=mode == "spill")
        st.n_preemptions += 1
        self.swapped[st.request.rid] = SwapEntry(
            state=st, kv=kv, preempted_step=self.engine.clock,
            spilled=mode == "spill")
        self.n_preemptions += 1
        self.n_spills += int(mode == "spill")
        self._drain_store_evictions()
        return st.request.rid

    def preempt_all(self, mode: Optional[str] = None) -> List[int]:
        """Yield every active slot — the contact-window entry point."""
        return [self.preempt(s, mode) for s in self.engine.slots.active_slots()]

    def _drain_store_evictions(self) -> None:
        """A spill-store eviction invalidates its rid's host snapshot
        lineage.  If that sequence is still swapped out spilled, the
        evicted record WAS its KV — drop the swap entry and redo the
        request from prefill (progress is discarded; greedy decode makes
        the redo token-exact).  A rid that already resumed (or swapped
        resident) merely loses delta eligibility: its live watermark is
        reset so its next spill ships the full live set again."""
        if self.store is None:
            return
        for rid in self.store.take_evicted():
            e = self.swapped.get(rid)
            if e is not None and e.spilled:
                del self.swapped[rid]
                # drop any shared-prefix refs the swap entry pinned —
                # the redo re-attaches them through the index
                self.engine.slots.discard_detached(e.state)
                self.engine.queue.requeue_front(e.state.request)
                self.n_redo_from_prefill += 1
                continue
            # still live (active slot or resident swap): pages [0,
            # synced) no longer have a host copy, so a stale watermark
            # would make the next spill a partial snapshot
            st = (e.state if e is not None else
                  next((s for s in self.engine.slots.states
                        if s is not None and s.request.rid == rid), None))
            if st is not None:
                # shared-prefix pages never ship, so the watermark
                # floors at the shared boundary, not 0
                st.synced_pages = getattr(st, "shared_pages", 0)

    def _redo_corrupt(self, entry: SwapEntry) -> None:
        """A spill record failed its checksum: the host copy is gone and
        was the ONLY copy, so the request redoes from prefill — the same
        recovery lane as a store eviction (greedy decode keeps the redo
        token-exact), never a garbage graft."""
        self.engine.slots.discard_detached(entry.state)
        self.engine.queue.requeue_front(entry.state.request)
        self.n_redo_from_corruption += 1

    def resume(self, rid: int, slot: int) -> None:
        """Re-place a swapped sequence into a free slot, token-exactly.
        If the sequence's spill record fails its integrity check the
        resume turns into a redo-from-prefill (the slot stays free)."""
        entry = self.swapped.pop(rid)
        t0 = time.perf_counter()
        kv = entry.kv
        from_store = (entry.spilled and kv is None and self.store is not None
                      and rid in self.store)
        if from_store:
            try:
                kv = self.store.snapshot(rid)
            except SpillCorruption:
                self._redo_corrupt(entry)
                return
        self.engine.slots.restore(slot, entry.state, kv,
                                  spilled=entry.spilled)
        if from_store:
            # every restored page now matches the host store's copy:
            # raise the watermark so the NEXT spill ships only pages
            # dirtied from here on (decode lowers it again per write)
            entry.state.synced_pages = len(entry.state.pages)
        self.resume_s.append(time.perf_counter() - t0)
        self.n_resumes += 1
        self.swapped_steps += self.engine.clock - entry.preempted_step

    # -- transmit-lane page hold (overlapped contact pipeline) ---------------
    def hold_pages(self, n: int) -> int:
        """Reserve ``n`` pool pages for a contact window's transmit lane
        (downlink staging buffers), spilling active sequences — lowest
        priority first, then the largest block table, so the fewest
        victims free the most pages — until the hold fits.  Everything
        not spilled keeps decoding through the pass; the spilled victims
        resume (token-exactly, via their delta snapshots) once
        ``release_hold`` returns the pages at window close.  Holds what
        is actually attainable and returns the total held; idempotent
        across the in-window ticks of one pass."""
        slots = self.engine.slots
        alloc = getattr(slots, "allocator", None)
        if alloc is None or n <= 0:
            return 0
        need = min(n, alloc.n_pages) - self.held_pages
        if need <= 0:
            return self.held_pages
        while alloc.available() < need and slots.any_active():
            # spilling a victim only returns its PRIVATE pages (shared
            # prefix refs stay pinned), so rank by reclaimable pages
            victims = sorted(
                slots.active_slots(),
                key=lambda s: (slots.states[s].request.priority,
                               -(len(slots.states[s].pages)
                                 - getattr(slots.states[s], "shared_pages",
                                           0)),
                               -slots.states[s].request.arrival_t,
                               slots.states[s].request.rid))
            self.preempt(victims[0], "spill")
        take = min(need, alloc.available())
        if take > 0:
            alloc.reserve(take)
            self.held_pages += take
        return self.held_pages

    def release_hold(self) -> None:
        """Return the transmit lane's page hold to the pool (window
        close) — spilled victims become resumable again."""
        if self.held_pages:
            self.engine.slots.allocator.release([],
                                                unreserve=self.held_pages)
            self.held_pages = 0

    # -- the scheduling loop -------------------------------------------------
    def _resume_order(self) -> List[SwapEntry]:
        return sorted(self.swapped.values(),
                      key=lambda e: (-e.priority, e.preempted_step, e.rid))

    def _arrived(self) -> List[Request]:
        return self.engine.queue.arrived(self.engine.clock)

    def _budget_pages(self, req: Request) -> int:
        slots = self.engine.slots
        if hasattr(slots, "_lifetime_pages"):
            return slots._lifetime_pages(req)
        return 0                               # contiguous: slots only

    def _fill_free_slots(self) -> None:
        """Fill free slots highest-priority-first: swapped sequences
        (they hold progress) compete with arrived queue entries; ties go
        to the earlier preemption/arrival.  Both lists keep a
        head-of-line discipline so a large request cannot be starved by
        a stream of smaller later ones: only the queue head (in priority
        order) is ever considered, and a spilled swap head whose pages
        are not yet reservable blocks later SPILLED entries (resident
        entries may still skip ahead — resuming them consumes no pages,
        so they cannot starve the head)."""
        slots = self.engine.slots
        for slot in slots.free_slots():
            cands: List[Tuple[tuple, str, object]] = []
            blocked_prio: Optional[int] = None
            for e in self._resume_order():
                if not slots.can_restore(e.state, e.spilled):
                    if blocked_prio is None:   # only spilled entries fail
                        blocked_prio = e.priority
                    continue
                if e.spilled and blocked_prio is not None:
                    continue                   # don't steal the head's pages
                cands.append(((-e.priority, e.preempted_step, e.rid),
                              "swap", e))
                break
            arrived = sorted(self._arrived(),
                             key=lambda r: (-r.priority, r.arrival_t, r.rid))
            if arrived and slots.can_admit(arrived[0]):
                r = arrived[0]
                # a blocked swap head also vetoes page-consuming queue
                # admissions of its own (or lower) priority — the swapped
                # sequence holds progress and must not be starved by a
                # steady stream of fresh arrivals
                if blocked_prio is None or r.priority > blocked_prio:
                    cands.append(((-r.priority, r.arrival_t, r.rid),
                                  "queue", r))
            if not cands:
                break
            _, kind, obj = min(cands)
            if kind == "swap":
                self.resume(obj.rid, slot)
            else:
                self.engine._admit(self.engine.queue.take(obj), slot)

    def _best_blocked(self) -> Optional[Tuple[Request, int]]:
        """Highest-priority waiting work that cannot be placed right now
        (no free slot, or — paged — not enough reservable pages), with
        the page count a placement would actually consume: the full
        lifetime budget for queue/spilled entries, zero for resident
        entries (their pages are still committed — only a slot is
        missing)."""
        slots = self.engine.slots
        free = bool(slots.free_slots())
        out: List[Tuple[tuple, Request, int]] = []
        for e in self.swapped.values():
            if not free or not slots.can_restore(e.state, e.spilled):
                # contiguous states carry no page budget: slots only
                need = getattr(e.state, "budget", 0) if e.spilled else 0
                out.append(((-e.priority, e.preempted_step, e.rid),
                            e.state.request, need))
        for r in self._arrived():
            if not free or not slots.can_admit(r):
                out.append(((-r.priority, r.arrival_t, r.rid), r,
                            self._budget_pages(r)))
        if not out:
            return None
        _, req, need = min(out)
        return req, need

    def _admit_by_priority(self) -> None:
        """Fill free slots, then let blocked higher-priority work spill
        STRICTLY-lower-priority active sequences — but only when
        reclaiming every such victim would actually cover the blocked
        request's page need (otherwise preemption is pure churn: the
        victim's pages can never add up to an admission)."""
        self._fill_free_slots()
        slots = self.engine.slots
        while True:
            blocked = self._best_blocked()
            if blocked is None:
                return
            best, need = blocked
            victims = [s for s in slots.active_slots()
                       if slots.states[s].request.priority < best.priority]
            if not victims:
                return
            alloc = getattr(slots, "allocator", None)
            if alloc is not None:
                reclaim = sum(slots.states[s].budget for s in victims)
                if alloc.available() + reclaim < need:
                    return                     # infeasible even spilling all
            # spill weakest-first until the blocked request fits
            victims.sort(key=lambda s: (slots.states[s].request.priority,
                                        -slots.states[s].request.arrival_t))
            for v in victims:
                self.preempt(v, "spill")       # frees the slot AND its pages
                if alloc is None or alloc.available() >= need:
                    break
            self._fill_free_slots()

    def step(self, *, decode: bool = True) -> List[int]:
        """One scheduler tick: resume/admit by priority, then one
        unified token-budget step (or an idle tick with ``decode=False``
        — a contact window holding the compute).  Returns rids finished
        this tick."""
        eng = self.engine
        before = len(eng.finish_order)
        self._drain_store_evictions()
        if decode:
            self._admit_by_priority()
            eng._unified_step()
        else:
            eng._idle_tick()                   # compute yielded
        finished = eng.finish_order[before:]
        if self.store is not None:
            for rid in finished:               # spill history is dead weight
                self.store.drop(rid)
        return finished

    def run(self, requests: Optional[List[Request]] = None,
            ) -> Dict[int, RequestResult]:
        """Drain: submit ``requests``, then step until queue, slots and
        swap ledger are all empty."""
        for r in sorted(requests or [], key=lambda r: r.arrival_t):
            self.submit(r)
        while self.has_work():
            self.step()
        return self.engine.results

    def stats(self) -> dict:
        lat = self.resume_s
        delta = (self.store.stats() if self.store is not None else
                 DeltaSpillStore.empty_stats())
        return {
            "n_preemptions": self.n_preemptions,
            "n_spills": self.n_spills,
            "n_resumes": self.n_resumes,
            "n_redo_from_prefill": self.n_redo_from_prefill,
            "n_redo_from_corruption": self.n_redo_from_corruption,
            "swapped_steps": self.swapped_steps,
            "resume_latency_s_mean": round(float(np.mean(lat)), 6) if lat
            else 0.0,
            "resume_latency_s_max": round(float(np.max(lat)), 6) if lat
            else 0.0,
            **delta,
        }

    # -- crash-safe checkpoint / restore -------------------------------------
    _COUNTER_KEYS = ("n_preemptions", "n_spills", "n_resumes",
                     "n_redo_from_prefill", "n_redo_from_corruption",
                     "swapped_steps")

    def checkpoint(self, path: str,
                   extra_meta: Optional[dict] = None) -> int:
        """Serialize the COMPLETE serving state — request queue, swap
        ledger (store-managed spill records materialize through
        ``DeltaSpillStore.snapshot``), active slot states with their
        live KV, finished results and cumulative counters — through
        ``repro.checkpoint.store``.  Non-destructive: the engine keeps
        running; call it periodically and a crash loses at most the
        work since the last call (``restore`` resumes token-exactly —
        greedy decode re-derives identical tokens from the snapshotted
        KV).  A spill record that fails its checksum here is handled
        like any detected corruption: the sequence redoes from prefill
        and enters the checkpoint as queued.  Returns bytes written."""
        eng = self.engine
        slots = eng.slots
        paged = hasattr(slots, "allocator")
        tree: Dict[str, np.ndarray] = {}
        seqs: List[dict] = []
        requests: Dict[int, Request] = {}

        def add_seq(st, kind: str, kv, preempted_step: int) -> None:
            rid = st.request.rid
            requests[rid] = st.request
            n = 0
            if kv is not None:
                leaves = jax.tree.leaves(kv)
                for i, leaf in enumerate(leaves):
                    tree[f"kv/{rid}/{i}"] = np.asarray(leaf)
                n = len(leaves)
            if st.last_logits is not None:
                tree[f"logits/{rid}"] = np.asarray(st.last_logits)
            seqs.append({
                "rid": int(rid), "kind": kind, "pos": int(st.pos),
                "next_tok": int(st.next_tok),
                "emitted": [int(x) for x in st.emitted],
                "admitted_step": int(st.admitted_step),
                "first_token_step": int(st.first_token_step),
                "phase": st.phase,
                "n_preemptions": int(st.n_preemptions),
                "preempted_step": int(preempted_step),
                "n_kv_leaves": n,
                "drafts": [int(x) for x in st.drafts],
            })

        # swapped entries first: materializing a store-managed spill can
        # DETECT a corrupted record, which requeues its request — the
        # queue must be serialized after that can no longer happen
        for e in list(self.swapped.values()):
            rid = e.rid
            if e.kv is not None:
                kv = e.kv
            elif not e.spilled:
                kv = slots.snapshot_state(e.state)   # resident (paged)
            elif self.store is not None and rid in self.store:
                try:
                    kv = self.store.snapshot(rid)
                except SpillCorruption:
                    del self.swapped[rid]
                    self._redo_corrupt(e)
                    continue
            else:
                kv = None    # PREFILLING spill before any page landed
            add_seq(e.state, "swapped", kv, e.preempted_step)
        for slot in slots.active_slots():
            add_seq(slots.states[slot], "active", slots.snapshot(slot),
                    eng.clock)
        queued = []
        for r in eng.queue.items():
            requests[r.rid] = r
            queued.append(int(r.rid))
        results_meta = {}
        for rid, res in eng.results.items():
            results_meta[str(rid)] = {
                "prompt_len": int(res.prompt_len),
                "admitted_step": int(res.admitted_step),
                "finished_step": int(res.finished_step),
                "first_token_step": int(res.first_token_step),
                "n_preemptions": int(res.n_preemptions),
            }
            tree[f"rtokens/{rid}"] = np.asarray(res.tokens)
            if res.logits_last is not None:
                tree[f"rlogits/{rid}"] = np.asarray(res.logits_last)
        req_meta = {}
        for rid, r in requests.items():
            req_meta[str(rid)] = {
                "max_new": int(r.max_new),
                "arrival_t": float(r.arrival_t),
                "priority": int(r.priority),
                "prefill_pos": int(r.prefill_pos),
            }
            tree[f"prompt/{rid}"] = np.asarray(r.prompt)
        all_rids = [*requests, *eng.results]
        meta = {
            "kv_layout": eng.kv_layout,
            "page_size": int(slots.page_size) if paged else 0,
            # axis names + sizes only (no device ids): a reboot may come
            # up on a different device set; snapshots are device_get
            # global arrays, so only the SHAPE of the mesh must agree
            "mesh": ([[str(a), int(eng.mesh.shape[a])]
                      for a in eng.mesh.axis_names]
                     if getattr(eng, "mesh", None) is not None else None),
            "clock": int(eng.clock),
            "prefill_tokens_total": int(eng.prefill_tokens_total),
            "finish_order": [int(x) for x in eng.finish_order],
            "queued": queued,
            "sequences": seqs,
            "requests": req_meta,
            "results": results_meta,
            "max_rid": int(max(all_rids)) if all_rids else -1,
            "sched": {k: int(getattr(self, k))
                      for k in self._COUNTER_KEYS},
            "store": (self.store.counters()
                      if self.store is not None else None),
            "extra": extra_meta or {},
        }
        return save_checkpoint(path, tree, meta=meta)

    def restore(self, path: str) -> dict:
        """Rebuild serving state from a checkpoint into THIS (fresh)
        scheduler/engine pair — the reboot path: device KV did not
        survive, so every checkpointed sequence re-enters as a spilled
        swap entry whose resume re-reserves pages and grafts the
        snapshotted KV back (bit-exact), and queued requests rejoin the
        queue in order.  Returns the checkpoint's ``extra`` meta."""
        eng = self.engine
        slots = eng.slots
        paged = hasattr(slots, "allocator")
        if (eng.clock != 0 or eng.results or eng.finish_order
                or len(eng.queue) or slots.any_active() or self.swapped):
            raise RuntimeError(
                "restore() needs a FRESH engine/scheduler (reboot builds "
                "new ones, e.g. via ContinuousEngine.clone_fresh)")
        leaves, meta = load_checkpoint_raw(path)
        if meta["kv_layout"] != eng.kv_layout:
            raise RuntimeError(
                f"checkpoint kv_layout {meta['kv_layout']!r} != engine "
                f"{eng.kv_layout!r}")
        if paged and meta["page_size"] != slots.page_size:
            raise RuntimeError(
                f"checkpoint page_size {meta['page_size']} != engine "
                f"{slots.page_size}")
        here = ([[str(a), int(eng.mesh.shape[a])]
                 for a in eng.mesh.axis_names]
                if getattr(eng, "mesh", None) is not None else None)
        if meta.get("mesh") != here:
            raise RuntimeError(
                f"checkpoint mesh {meta.get('mesh')} != engine {here} — "
                "restore into an engine with the same mesh axis shape "
                "(device identities may differ)")
        treedef = jax.tree.structure(slots.cache)

        def kv_of(rid: int, n: int):
            if n == 0:
                return None
            return jax.tree.unflatten(
                treedef, [leaves[f"kv/{rid}/{i}"] for i in range(n)])

        requests: Dict[int, Request] = {}
        for rid_s, r in meta["requests"].items():
            rid = int(rid_s)
            requests[rid] = Request(
                prompt=np.asarray(leaves[f"prompt/{rid}"]),
                max_new=int(r["max_new"]), rid=rid,
                arrival_t=float(r["arrival_t"]),
                priority=int(r["priority"]),
                prefill_pos=int(r["prefill_pos"]))
        eng.clock = int(meta["clock"])
        eng.prefill_tokens_total = int(meta["prefill_tokens_total"])
        eng.finish_order = [int(x) for x in meta["finish_order"]]
        for rid_s, r in meta["results"].items():
            rid = int(rid_s)
            eng.results[rid] = RequestResult(
                rid=rid, tokens=leaves[f"rtokens/{rid}"],
                prompt_len=int(r["prompt_len"]),
                admitted_step=int(r["admitted_step"]),
                finished_step=int(r["finished_step"]),
                first_token_step=int(r["first_token_step"]),
                n_preemptions=int(r["n_preemptions"]),
                logits_last=leaves.get(f"rlogits/{rid}"))
        for rid in meta["queued"]:
            eng.queue.submit(requests[int(rid)])
        for s in meta["sequences"]:
            rid = int(s["rid"])
            req = requests[rid]
            common = dict(request=req, pos=int(s["pos"]),
                          next_tok=int(s["next_tok"]),
                          emitted=[int(x) for x in s["emitted"]],
                          admitted_step=int(s["admitted_step"]),
                          first_token_step=int(s["first_token_step"]),
                          phase=s["phase"],
                          n_preemptions=int(s["n_preemptions"]),
                          last_logits=leaves.get(f"logits/{rid}"),
                          drafts=[int(x) for x in s.get("drafts", [])])
            if paged:
                # shared-prefix refs died with the old pool: the restored
                # entry is fully private, budgeted for its whole lifetime
                st = _PagedSlotState(**common, pages=[],
                                     budget=slots._lifetime_pages(req),
                                     synced_pages=0, shared_pages=0)
            else:
                st = _SlotState(**common)
            self.swapped[rid] = SwapEntry(
                state=st, kv=kv_of(rid, int(s["n_kv_leaves"])),
                preempted_step=int(s["preempted_step"]), spilled=True)
        for k in self._COUNTER_KEYS:
            setattr(self, k, int(meta["sched"][k]))
        if self.store is not None and meta.get("store"):
            self.store.load_counters(meta["store"])
        # restored rids must never collide with future fresh Requests
        ensure_rid_floor(int(meta["max_rid"]) + 1)
        return meta.get("extra", {})


# ==========================================================================
# space-ground tiering
# ==========================================================================

@dataclass
class SpaceGroundReport:
    """Final answers plus the byte/energy ledger of one replay."""
    tokens: Dict[int, np.ndarray]       # rid -> final token stream
    sat_results: Dict[int, RequestResult]
    ground_results: Dict[int, RequestResult]
    escalated: List[int]                # rids re-answered by the ground tier
    undelivered: List[int]              # rids whose downlink missed the horizon
    ledger: Ledger = field(default_factory=Ledger)
    n_preemptions: int = 0
    windows: List[Tuple[int, int]] = field(default_factory=list)
    sat_stats: dict = field(default_factory=dict)   # PreemptiveScheduler.stats
    decode_steps_in_window: int = 0     # overlap: decode ticks during passes
    n_reboots: int = 0                  # injected crashes survived via restore
    lane_stats: dict = field(default_factory=dict)  # TransmitLane.state()
    spec_stats: dict = field(default_factory=dict)  # ground-tier draft-verify
    #                                     counters (ContinuousEngine.spec_stats)


class SpaceGroundScheduler:
    """Two-tier scheduling between a satellite and a ground engine.

    Each ground-station pass (``ContactSchedule`` quantized to decode
    ticks via ``step_windows``) is split into two lanes:

      * a **transmit lane** (``core.link.TransmitLane``) draining the
        downlink backlog incrementally against the pass's per-tick byte
        budget, in FIFO order: (a) compact results of confident finished
        sequences, (b) raw prompts of low-confidence ones — the
        ``core/cascade`` gate decides which — which the ground engine
        then re-answers.  With ``speculative=True`` an escalation ships
        only the satellite's DRAFT TOKEN IDS
        (``core.link.payload_bytes_draft`` — the ground already holds
        the prompt from the uplink relay, exactly as the raw path
        already assumes when it resubmits ``by_rid[rid]``) and the
        ground engine verifies the whole draft stream in chunked
        passes (``ContinuousEngine.attach_drafts``) instead of
        re-decoding token-by-token — same greedy answers, a fraction
        of the downlink bytes and of the ground decode ticks; and
      * a **compute lane**: with ``overlap`` (the default) satellite
        decode *continues through the pass*, interleaved one decode
        step per transmitted tick.  Only the transmit lane's staging
        reserve (``comm_reserve_pages`` KV pages held for the pass via
        ``PreemptiveScheduler.hold_pages``) can force preemption, and
        only of the sequences whose pages must spill to cover it; the
        rest never stop.  Spilled victims resume token-exactly after
        the pass — re-preempted long sequences ship only KV-delta
        pages.  ``overlap=False`` is PR 3's stop-the-world behavior:
        every in-flight sequence preempted for the whole pass.

    The ground tier is always-on (it's on Earth) and steps once per
    satellite tick.

    Deterministic: the only clock is the satellite engine's decode tick
    (``s_per_step`` seconds each), so the same trace + schedule replays
    to identical tokens, preemptions, and ledger totals.
    """

    def __init__(self, sat_engine: ContinuousEngine,
                 ground_engine: ContinuousEngine, *,
                 schedule: Optional[ContactSchedule] = None,
                 gate: Optional[ConfidenceGate] = None,
                 energy: Optional[EnergyModel] = None,
                 s_per_step: float = 0.35,
                 horizon_s: float = 86_400.0,
                 preempt_mode: str = "spill",
                 overlap: bool = True,
                 comm_reserve_pages: int = 2,
                 delta_spill: bool = True,
                 frame_bytes: Optional[int] = None,
                 link_max_retries: int = 8,
                 faults: Optional[FaultInjector] = None,
                 checkpoint_every: int = 0,
                 checkpoint_path: Optional[str] = None,
                 speculative: bool = False):
        self._sat_kw = dict(preempt_mode=preempt_mode,
                            delta_spill=delta_spill)
        self.faults = faults
        self.sat = PreemptiveScheduler(sat_engine, fault_injector=faults,
                                       **self._sat_kw)
        self.overlap = overlap
        self.comm_reserve_pages = comm_reserve_pages
        self.ground = ground_engine
        self.speculative = speculative
        if speculative and ground_engine.kv_layout != "paged":
            raise ValueError(
                "speculative escalation needs a paged-layout ground "
                "engine (draft verification runs through the chunk path)")
        # fresh default instances per scheduler: the models hold mutable
        # dict fields a caller may tune (e.g. energy.subsystem_w)
        self.schedule = schedule if schedule is not None else ContactSchedule()
        self.gate = gate if gate is not None else ConfidenceGate()
        self.energy = energy if energy is not None else EnergyModel()
        self.s_per_step = s_per_step
        self.horizon_steps = int(horizon_s / s_per_step)
        self.windows = self.schedule.step_windows(s_per_step, horizon_s)
        self.frame_bytes = frame_bytes
        self.link_max_retries = link_max_retries
        self.checkpoint_every = int(checkpoint_every)
        if faults is not None:
            p = faults.plan
            if ((p.frame_loss_rate > 0.0 or p.frame_corrupt_rate > 0.0)
                    and frame_bytes is None):
                raise ValueError(
                    "a lossy FaultPlan needs frame_bytes: only the framed "
                    "lane can detect loss/corruption and retransmit")
            if p.crash_at_tick is not None and self.checkpoint_every <= 0:
                raise ValueError(
                    "FaultPlan schedules a crash but checkpoint_every is "
                    "0 — there would be nothing to restore from")
            # early LOS: ionospheric scintillation cuts passes short
            self.windows = faults.truncate_step_windows(self.windows)
        if self.checkpoint_every > 0 and checkpoint_path is None:
            checkpoint_path = os.path.join(
                tempfile.mkdtemp(prefix="sgs_ckpt_"), "sat.ckpt")
        self._ckpt_path = checkpoint_path
        # downlink budget per in-window tick, derived from the link
        # model's own loss-adjusted rate (downlink_time_s(1) = s/byte)
        self.bytes_per_step = (s_per_step
                               / self.schedule.link.downlink_time_s(1.0))

    def _in_window(self, t: int) -> bool:
        return any(lo <= t < hi for lo, hi in self.windows)

    def _next_window_start(self, t: int) -> Optional[int]:
        starts = [lo for lo, hi in self.windows if hi > t]
        return min(starts) if starts else None

    def _make_lane(self) -> TransmitLane:
        if self.frame_bytes is not None:
            return TransmitLane(frame_bytes=self.frame_bytes,
                                max_retries=self.link_max_retries,
                                injector=self.faults)
        return TransmitLane()

    def _write_checkpoint(self, lane: TransmitLane) -> None:
        """Checkpoint the full satellite side: serving state through
        ``PreemptiveScheduler.checkpoint`` plus the downlink backlog,
        lane counters and injector state as ``extra`` meta, so a reboot
        rolls the WHOLE satellite back to one consistent instant (the
        injector's RNG rolls back too — post-restore fault draws replay
        identically, keeping injected == detected accounting exact)."""
        extra = {
            "lane": [[int(rid), bool(esc), float(nb)]
                     for (rid, esc), nb in lane.pending_payloads()],
            "lane_state": lane.state(),
        }
        if self.faults is not None:
            extra["faults"] = self.faults.state()
        self.sat.checkpoint(self._ckpt_path, extra_meta=extra)

    def _reboot(self) -> TransmitLane:
        """Simulated satellite reboot: device memory and every live
        Python object on the sat side are gone; rebuild a fresh engine
        (weights persist — they live in the read-only image) + scheduler
        + lane from the last checkpoint.  Ground-side state is on Earth
        and survives untouched."""
        eng = self.sat.engine.clone_fresh()
        self.sat = PreemptiveScheduler(eng, fault_injector=self.faults,
                                       **self._sat_kw)
        extra = self.sat.restore(self._ckpt_path)
        lane = self._make_lane()
        for rid, esc, nb in extra["lane"]:
            lane.enqueue((int(rid), bool(esc)), float(nb))
        lane.load_state(extra["lane_state"])
        if self.faults is not None and "faults" in extra:
            self.faults.load_state(extra["faults"])
        return lane

    def run(self, requests: List[Request]) -> SpaceGroundReport:
        rep = SpaceGroundReport(tokens={}, sat_results={}, ground_results={},
                                escalated=[], undelivered=[],
                                windows=list(self.windows))
        led = rep.ledger
        for r in sorted(requests, key=lambda r: r.arrival_t):
            self.sat.submit(r)
        by_rid = {r.rid: r for r in requests}
        ground_to_rid: Dict[int, int] = {}
        lane = self._make_lane()         # items: (rid, escalate)
        # ground-side memory: a crash rolls the SATELLITE back to its
        # last checkpoint, so work finished/downlinked in between is
        # redone and re-delivered — Earth must not double-count it
        classified: set = set()          # rids already in the ledger
        delivered: set = set()           # rids already landed on Earth
        last_ckpt: Optional[int] = None

        def classify(rid: int) -> None:
            """Queue a finished satellite sequence for downlink."""
            res = self.sat.results[rid]
            rep.sat_results[rid] = res
            dec = self.gate.decide(res.logits_last[None])
            esc = bool(np.asarray(dec["escalate"])[0])
            if not esc:
                nbytes = payload_bytes_result(len(res.tokens))
            elif self.speculative:
                # the ground tier verifies the satellite's draft instead
                # of re-decoding from the (already-relayed) raw prompt:
                # only the draft token ids cross the downlink
                nbytes = payload_bytes_draft(len(res.tokens))
            else:
                nbytes = payload_bytes_raw(1, (res.prompt_len,), 4)
            if rid not in classified:    # a post-reboot redo re-finishes
                classified.add(rid)
                led.add("items_total", 1)
                led.add("items_escalated", int(esc))
                led.add("bytes_results", 0 if esc else nbytes)
                if self.speculative:
                    led.add("bytes_draft_escalated", nbytes if esc else 0)
                    led.add("draft_tokens_shipped",
                            len(res.tokens) if esc else 0)
                else:
                    led.add("bytes_raw_escalated", nbytes if esc else 0)
                led.add("bytes_bentpipe_baseline",
                        payload_bytes_raw(1, (res.prompt_len,), 4))
            lane.enqueue((rid, esc), nbytes)

        def decode_tick(in_window: bool) -> None:
            """One compute-lane tick: decode, meter energy, classify."""
            finished = self.sat.step()
            if self.sat.engine.slots.any_active() or finished:
                led.add("energy_compute_j",
                        self.energy.inference_energy_j(1, self.s_per_step))
                if in_window:
                    rep.decode_steps_in_window += 1
            for rid in finished:
                classify(rid)

        t = self.sat.clock
        while True:
            ground_busy = bool(len(self.ground.queue)
                               or self.ground.slots.any_active())
            if not (self.sat.has_work() or len(lane) or ground_busy):
                break
            if t >= self.horizon_steps and not (self.sat.has_work()
                                                or ground_busy):
                # backlog missed every window: record, don't silently drop
                rep.undelivered = [rid for rid, _ in lane.clear()]
                break
            if (self._ckpt_path is not None and self.checkpoint_every > 0
                    and (last_ckpt is None
                         or t - last_ckpt >= self.checkpoint_every)):
                self._write_checkpoint(lane)
                last_ckpt = t
            if self.faults is not None and self.faults.crash_due(t):
                # injected satellite reboot: everything on the sat side
                # rolls back to the last checkpoint and replays
                # token-exactly; Earth keeps what already landed
                self.faults.note_crash()
                rep.n_reboots += 1
                lane = self._reboot()
                t = self.sat.clock
                last_ckpt = t            # restore IS the checkpoint state
                continue
            in_window = self._in_window(t)
            if in_window:
                if self.overlap:
                    # compute keeps running: hold only the transmit
                    # lane's staging reserve, spilling the fewest
                    # sequences whose pages must cover it
                    self.sat.hold_pages(self.comm_reserve_pages)
                else:
                    # PR 3 stop-the-world: the pass holds the compute
                    self.sat.preempt_all()
                # the transmit lane drains this tick's byte budget FIFO
                tx_active = len(lane) > 0
                sent_before = lane.bytes_sent
                lost_before = lane.bytes_lost
                retx_before = lane.bytes_retransmitted
                for rid, esc in lane.tick(self.bytes_per_step):
                    if rid in delivered:
                        continue         # post-reboot re-delivery: Earth
                        #                  already has this answer
                    delivered.add(rid)
                    if esc:
                        rep.escalated.append(rid)
                        src = by_rid[rid]
                        # clone keeps priority/prompt/max_new; arrival
                        # is the downlink tick the answer landed on the
                        # ground, so ground-tier admission order matches
                        # downlink order (not a flat 0.0 for everyone)
                        g = src.clone()
                        g.arrival_t = float(self.ground.clock)
                        if self.speculative:
                            # the landed payload IS the draft stream:
                            # the ground verifies it in chunked passes
                            # rather than re-decoding the prompt
                            g.draft_toks = np.asarray(
                                rep.sat_results[rid].tokens, np.int32)
                        ground_to_rid[g.rid] = rid
                        self.ground.submit(g)
                # a payload that burned its whole retry budget goes back
                # on the queue: the satellite never silently drops an
                # answer — it re-ships (and re-meters) until it lands
                for item, nb in lane.take_failed():
                    led.add("n_payload_retransmits", 1)
                    lane.enqueue(item, nb)
                if tx_active:
                    led.add("bytes_downlinked", lane.bytes_sent - sent_before)
                    if lane.framed:
                        led.add("bytes_lost", lane.bytes_lost - lost_before)
                        led.add("bytes_retransmitted",
                                lane.bytes_retransmitted - retx_before)
                    led.add("downlink_s", self.s_per_step)
                    led.add("energy_comm_j",
                            self.energy.comm_energy_j(self.s_per_step))
                if self.overlap:
                    decode_tick(True)    # compute lane: same tick
                else:
                    self.sat.step(decode=False)
                    # stop-the-world invariant tripwire: preempt_all
                    # just ran, so an active slot here means decode
                    # leaked into the pass — surface it in the metric
                    # instead of silently reporting 0
                    if self.sat.engine.slots.any_active():
                        rep.decode_steps_in_window += 1
            else:
                self.sat.release_hold()  # window closed: staging pages back
                if self.sat.has_work():
                    decode_tick(False)
                elif len(lane):
                    nxt = self._next_window_start(t)
                    if nxt is None:      # no pass left in the horizon
                        rep.undelivered = [rid for rid, _ in lane.clear()]
                        continue
                    self.sat.engine.clock = nxt     # sleep to the next pass
                    # the ground tier gets the whole inter-pass gap, not
                    # one tick: drain whatever it is already decoding
                    while (len(self.ground.queue)
                           or self.ground.slots.any_active()):
                        self.ground.step()
                else:
                    self.sat.step()      # idle tick: wait for arrivals
            self.ground.step()           # always-on tier
            t = self.sat.clock

        self.sat.release_hold()          # horizon may end mid-window
        # drain the ground tier (it may still be decoding escalations)
        while len(self.ground.queue) or self.ground.slots.any_active():
            self.ground.step()

        rep.ground_results = {ground_to_rid[grid]: res
                              for grid, res in self.ground.results.items()
                              if grid in ground_to_rid}
        for rid, res in rep.sat_results.items():
            if rid in rep.ground_results:
                rep.tokens[rid] = rep.ground_results[rid].tokens
            else:
                rep.tokens[rid] = res.tokens
        rep.n_preemptions = self.sat.n_preemptions
        rep.sat_stats = self.sat.stats()
        rep.lane_stats = lane.state()
        if self.speculative:
            rep.spec_stats = self.ground.spec_stats()
        return rep
