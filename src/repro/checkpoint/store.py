"""Checkpointing: msgpack (+ optional zstd) over a flattened pytree.

No orbax in this environment; this is a self-contained, deterministic
format.  Layout: a single ``.ckpt`` file holding
    {"meta": {...}, "leaves": {path: {dtype, shape, codec, raw}}}
Each leaf records its ``codec`` ("zstd" or "raw") so a file written on a
host with ``zstandard`` installed loads on one without it and vice
versa — compression is an optimization, never a format requirement.
Loading restores into the exact tree structure via a template pytree
(shape/dtype checked leaf by leaf).  bf16 round-trips via a uint16 view.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard as zstd
except ImportError:              # pragma: no cover - env dependent
    zstd = None


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


def save_checkpoint(path: str, tree: Any, meta: Optional[dict] = None,
                    level: int = 3) -> int:
    """Returns the on-disk size in bytes."""
    cctx = zstd.ZstdCompressor(level=level) if zstd is not None else None
    leaves = {}
    for p, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        view = arr.view(np.uint16) if arr.dtype == jnp.bfloat16 else arr
        payload = np.ascontiguousarray(view).tobytes()
        leaves[_path_str(p)] = {
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "codec": "zstd" if cctx is not None else "raw",
            "raw": cctx.compress(payload) if cctx is not None else payload,
        }
    blob = msgpack.packb({"meta": meta or {}, "leaves": leaves},
                         use_bin_type=True)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "wb") as f:
        f.write(blob)
    return len(blob)


def _decode_payload(rec: dict) -> bytes:
    # files written before codecs were recorded are always zstd
    codec = rec.get("codec", "zstd")
    if codec == "raw":
        return rec["raw"]
    if codec == "zstd":
        if zstd is None:
            raise RuntimeError(
                "checkpoint leaf is zstd-compressed but the 'zstandard' "
                "module is not installed")
        return zstd.ZstdDecompressor().decompress(rec["raw"])
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def load_checkpoint_raw(path: str):
    """Template-free load: ``({path_str: np.ndarray}, meta)``.

    The crash-recovery checkpoint (``serving.scheduler``) stores a FLAT
    dict keyed by string paths — per-sequence KV snapshots whose set of
    keys depends on runtime serving state, so no static template pytree
    can describe it.  bf16 leaves come back as ``ml_dtypes.bfloat16``
    via jax, matching what ``save_checkpoint`` was handed."""
    with open(path, "rb") as f:
        obj = msgpack.unpackb(f.read(), raw=False)
    out = {}
    for key, rec in obj["leaves"].items():
        raw = _decode_payload(rec)
        shape = tuple(rec["shape"])
        if rec["dtype"] == "bfloat16":
            arr = np.asarray(jnp.asarray(
                np.frombuffer(raw, np.uint16).reshape(shape)
            ).view(jnp.bfloat16))
        else:
            arr = np.frombuffer(raw, np.dtype(rec["dtype"])).reshape(shape)
        out[key] = arr
    return out, obj["meta"]


def load_checkpoint(path: str, template: Any):
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs).  Returns (tree, meta)."""
    with open(path, "rb") as f:
        obj = msgpack.unpackb(f.read(), raw=False)
    leaves_in = obj["leaves"]

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for p, leaf in paths:
        key = _path_str(p)
        if key not in leaves_in:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        rec = leaves_in[key]
        want_shape = tuple(leaf.shape)
        if tuple(rec["shape"]) != want_shape:
            raise ValueError(f"{key}: shape {rec['shape']} != {want_shape}")
        raw = _decode_payload(rec)
        if rec["dtype"] == "bfloat16":
            arr = np.frombuffer(raw, np.uint16).reshape(want_shape)
            arr = jnp.asarray(arr).view(jnp.bfloat16)
        else:
            arr = jnp.asarray(
                np.frombuffer(raw, np.dtype(rec["dtype"])).reshape(want_shape))
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), obj["meta"]
