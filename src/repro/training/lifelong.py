"""Lifelong learning (paper §3.4): satellites face data drift and
catastrophic forgetting; a cloud-side KNOWLEDGE LIBRARY stores per-task
knowledge, and onboard updates combine incremental training with
rehearsal over library samples so earlier scenarios are not forgotten.

Implementation: the library keeps, per task/scenario, (a) a compact
replay buffer of batches and (b) the post-task parameter snapshot.
``lifelong_update`` fine-tunes on the new scenario while mixing replayed
batches from every known scenario (experience rehearsal — the simplest
robust continual-learning baseline), and registers the new scenario in
the library afterwards.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Iterable, Iterator, List, Optional

import numpy as np

from repro.config import ModelConfig
from repro.training import optim
from repro.training.loop import TrainState, train


@dataclass
class KnowledgeLibrary:
    """Cloud-side per-scenario knowledge store."""
    replay: Dict[str, List[dict]] = field(default_factory=dict)
    snapshots: Dict[str, dict] = field(default_factory=dict)
    max_batches_per_task: int = 8

    def register(self, task: str, batches: List[dict],
                 params: Optional[dict] = None) -> None:
        self.replay[task] = list(batches)[: self.max_batches_per_task]
        if params is not None:
            self.snapshots[task] = params

    def tasks(self) -> List[str]:
        return list(self.replay)

    def rehearsal_iter(self, seed: int = 0) -> Iterator[dict]:
        """Round-robin over stored tasks' replay batches, forever."""
        rng = np.random.default_rng(seed)
        tasks = self.tasks()
        while True:
            for t in tasks:
                buf = self.replay[t]
                yield buf[int(rng.integers(0, len(buf)))]


@dataclass(frozen=True)
class LifelongConfig:
    steps_per_task: int = 20
    rehearsal_ratio: float = 0.5       # fraction of steps from the library
    lr: float = 1e-3


def _mixed_stream(new_data: Iterator[dict], library: KnowledgeLibrary,
                  ratio: float, seed: int = 0) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    rehearsal = library.rehearsal_iter(seed) if library.tasks() else None
    while True:
        if rehearsal is not None and rng.random() < ratio:
            yield next(rehearsal)
        else:
            yield next(new_data)


def lifelong_update(cfg: ModelConfig, state: TrainState, task: str,
                    new_data: Iterable[dict], library: KnowledgeLibrary,
                    *, ll: LifelongConfig = LifelongConfig()) -> TrainState:
    """Adapt to a new scenario with rehearsal, then register it."""
    it = iter(new_data)
    # reserve some fresh batches for the replay buffer
    reserve = [next(it) for _ in range(library.max_batches_per_task)]
    stream = _mixed_stream(itertools.chain(reserve, it), library,
                           ll.rehearsal_ratio)
    opt_cfg = optim.OptimConfig(lr=ll.lr, warmup_steps=2,
                                total_steps=ll.steps_per_task)
    state.opt_state = optim.adamw_init(state.params, opt_cfg)
    state = train(cfg, state, stream, opt_cfg, steps=ll.steps_per_task,
                  log_every=max(ll.steps_per_task // 2, 1))
    library.register(task, reserve, state.params)
    return state
