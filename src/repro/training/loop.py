"""Single-host training loop used by examples and tests (the multi-pod
path goes through launch/train.py with pjit)."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import transformer as T
from repro.training import optim


@dataclass
class TrainState:
    params: dict
    opt_state: dict
    step: int = 0
    history: list = field(default_factory=list)


def init_state(cfg: ModelConfig, opt_cfg: optim.OptimConfig, *,
               seed: int = 0, max_seq: int = 4096) -> TrainState:
    params = T.init_params(jax.random.PRNGKey(seed), cfg, max_seq=max_seq)
    return TrainState(params=params,
                      opt_state=optim.adamw_init(params, opt_cfg))


def train(cfg: ModelConfig, state: TrainState, data: Iterable[dict],
          opt_cfg: optim.OptimConfig, *, steps: int,
          log_every: int = 20,
          callback: Optional[Callable] = None) -> TrainState:
    @jax.jit
    def step_fn(params, opt_state, batch):
        def lf(p):
            return T.loss_fn(p, cfg, batch)
        (_, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        params, opt_state, om = optim.adamw_update(params, grads, opt_state,
                                                   opt_cfg)
        return params, opt_state, {**metrics, **om}

    it = iter(data)
    t0 = time.time()
    for _ in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state.params, state.opt_state, m = step_fn(
            state.params, state.opt_state, batch)
        state.step += 1
        if state.step % log_every == 0 or state.step == 1:
            row = {k: float(v) for k, v in m.items()}
            row["step"] = state.step
            row["wall_s"] = round(time.time() - t0, 2)
            state.history.append(row)
            if callback:
                callback(row)
    return state
