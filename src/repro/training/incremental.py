"""Incremental training (paper §3.4): the deployed onboard model drifts
as the data distribution changes (weather, season); satellites collect
new data, the cloud fine-tunes, and the satellite pulls the refreshed
weights at the next contact."""
from __future__ import annotations

from dataclasses import dataclass

from repro.config import ModelConfig
from repro.training import optim
from repro.training.loop import TrainState, train


@dataclass(frozen=True)
class IncrementalConfig:
    finetune_steps: int = 30
    lr: float = 3e-4


def incremental_update(cfg: ModelConfig, state: TrainState, new_data, *,
                       inc: IncrementalConfig = IncrementalConfig()):
    """Fine-tune the current weights on the drifted distribution."""
    opt_cfg = optim.OptimConfig(lr=inc.lr, warmup_steps=5,
                                total_steps=inc.finetune_steps)
    state.opt_state = optim.adamw_init(state.params, opt_cfg)
    return train(cfg, state, new_data, opt_cfg, steps=inc.finetune_steps,
                 log_every=max(inc.finetune_steps // 3, 1))
