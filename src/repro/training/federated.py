"""Federated learning across satellite nodes (paper §3.4).

Each satellite trains on its LOCAL data shard (privacy: raw data never
leaves the satellite — only parameters do) and uploads weights when a
ground contact occurs.  The ground aggregates with staleness-discounted
FedAvg (satellites see the ground at different times; FedSpace-style
scheduling [paper ref 16]).

Implemented with explicit per-node states + the orchestration bus's
contact gating, so the aggregation schedule is the real schedule the
constellation would see.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core.link import ContactSchedule
from repro.models import transformer as T
from repro.training import optim
from repro.training.loop import TrainState, init_state, train


@dataclass(frozen=True)
class FedConfig:
    n_satellites: int = 3
    local_steps: int = 10
    rounds: int = 3
    staleness_half_life_s: float = 5_400.0     # ~1 orbit
    seed: int = 0


def _tree_scale(tree, s):
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * s), tree)


def _tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


def fedavg(global_params, sat_params: List[dict], weights: List[float]):
    """Staleness-weighted FedAvg; residual weight stays on the global."""
    wsum = sum(weights)
    if wsum <= 0:
        return global_params
    norm = [w / max(wsum, 1.0) for w in weights]
    rest = max(0.0, 1.0 - sum(norm))
    acc = _tree_scale(global_params, rest)
    for p, w in zip(sat_params, norm):
        acc = _tree_add(acc, _tree_scale(p, w))
    return jax.tree.map(lambda x, ref: x.astype(ref.dtype), acc,
                        global_params)


def run_federated(cfg: ModelConfig, fed: FedConfig, make_data, *,
                  opt_cfg: optim.OptimConfig = optim.OptimConfig(lr=1e-3),
                  max_seq: int = 256) -> dict:
    """make_data(sat_idx) -> iterable of batches (the satellite's shard).
    Returns {"global_params", "rounds": [...telemetry...]}."""
    g_state = init_state(cfg, opt_cfg, seed=fed.seed, max_seq=max_seq)
    global_params = g_state.params
    schedules = [ContactSchedule(seed=i) for i in range(fed.n_satellites)]
    telemetry = []
    t = 0.0
    for rnd in range(fed.rounds):
        sat_params, weights, losses = [], [], []
        for i in range(fed.n_satellites):
            st = TrainState(params=global_params,
                            opt_state=optim.adamw_init(global_params,
                                                       opt_cfg))
            st = train(cfg, st, make_data(i), opt_cfg,
                       steps=fed.local_steps, log_every=fed.local_steps)
            # contact gating: weight by staleness at the next uplink
            win = schedules[i].next_window(t)
            delay = (win[0] - t) if win else fed.staleness_half_life_s * 4
            w = 0.5 ** (delay / fed.staleness_half_life_s)
            sat_params.append(st.params)
            weights.append(w)
            losses.append(st.history[-1]["loss"] if st.history else None)
        global_params = fedavg(global_params, sat_params, weights)
        t += 5_400.0                                  # one orbit per round
        telemetry.append({"round": rnd, "weights": weights,
                          "local_losses": losses})
    return {"global_params": global_params, "rounds": telemetry}
