"""AdamW + cosine schedule with linear warmup, in pure JAX.

Moment dtype is configurable: fp32 by default, bf16 for very large
models (deepseek-v3's optimizer state would not fit 256 x 16 GB chips in
fp32 — see EXPERIMENTS.md §Dry-run)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def lr_schedule(cfg: OptimConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, 0.1 + 0.9 * cos)


def adamw_init(params, cfg: OptimConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: OptimConfig):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = mu_n / c1
        vhat = nu_n / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_n = p.astype(jnp.float32) - lr * delta
        return p_n.astype(p.dtype), mu_n.astype(mdt), nu_n.astype(mdt)

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    # unzip the 3-tuples
    params_n = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda t: isinstance(t, tuple))
    mu_n = jax.tree.map(lambda t: t[1], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    nu_n = jax.tree.map(lambda t: t[2], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gn, "lr": lr}
    return params_n, {"mu": mu_n, "nu": nu_n, "step": step}, metrics
