"""Training launcher.

Two modes:
  * local (default): run REAL steps of a reduced config on the host
    devices — this is what examples/train_100m.py drives;
  * --dry-run: lower + compile the FULL config on the production mesh
    (delegates to repro.launch.dryrun).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 50 --batch 8 --seq 256 [--reduced]
"""
from __future__ import annotations

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    if args.dry_run:
        from repro.launch.dryrun import dryrun_one
        dryrun_one(args.arch, args.shape)
        return

    import jax.numpy as jnp
    from repro.config import get_config, get_reduced_config
    from repro.data.tokens import TokenStream, TokenStreamConfig
    from repro.training import optim
    from repro.training.loop import init_state, train

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    opt_cfg = optim.OptimConfig(lr=args.lr, warmup_steps=10,
                                total_steps=args.steps)
    stream = TokenStream(TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        batch_size=args.batch))

    def add_extras(it):
        for b in it:
            if cfg.family == "vlm":
                b["patch_embeds"] = 0.01 * jnp.ones(
                    (args.batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
            if cfg.family == "audio":
                b["audio_frames"] = 0.01 * jnp.ones(
                    (args.batch, cfg.n_audio_frames, cfg.d_model),
                    jnp.bfloat16)
            yield b

    state = init_state(cfg, opt_cfg, max_seq=args.seq)
    state = train(cfg, state, add_extras(iter(stream)), opt_cfg,
                  steps=args.steps, log_every=10,
                  callback=lambda row: print(json.dumps(row)))
    if args.checkpoint:
        from repro.checkpoint import save_checkpoint
        n = save_checkpoint(args.checkpoint, state.params,
                            {"arch": cfg.name, "step": state.step})
        print(f"checkpoint: {args.checkpoint} ({n/1e6:.1f} MB)")


if __name__ == "__main__":
    main()
