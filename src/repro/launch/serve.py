"""Serving launcher: batched generation with a KV cache on the host
devices (reduced configs), or --dry-run to lower the full config's
serve_step on the production mesh.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-1.3b \
        --reduced --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--continuous", action="store_true",
                    help="serve through the continuous-batching engine "
                         "(batch = number of requests, slots = --batch)")
    args = ap.parse_args()

    if args.dry_run:
        from repro.launch.dryrun import dryrun_one
        dryrun_one(args.arch, args.shape)
        return

    from repro.config import get_config, get_reduced_config
    from repro.serving.engine import ServingEngine

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    rng = np.random.default_rng(0)
    if args.continuous:
        from repro.serving.batching import Request
        from repro.serving.engine import ContinuousEngine
        eng = ContinuousEngine.init(cfg, n_slots=args.batch,
                                    max_seq=args.max_seq)
        reqs = [Request(prompt=rng.integers(
                    0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                        max_new=args.max_new, arrival_t=float(i))
                for i in range(2 * args.batch)]
        results = eng.run(reqs)
        print("generated tokens (continuous, finish order "
              f"{eng.finish_order}):")
        for rid in sorted(results):
            print(f"  rid={rid}", results[rid].tokens.tolist())
        return
    eng = ServingEngine.init(cfg, max_seq=args.max_seq)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(args.batch, args.prompt_len)).astype(np.int32)
    extra = {}
    if cfg.family == "vlm":
        extra["patch_embeds"] = 0.01 * np.ones(
            (args.batch, cfg.n_patches, cfg.d_model), np.float32)
    if cfg.family == "audio":
        extra["audio_frames"] = 0.01 * np.ones(
            (args.batch, cfg.n_audio_frames, cfg.d_model), np.float32)
    res = eng.generate(prompts, max_new=args.max_new,
                       extra_inputs=extra or None)
    print("generated tokens:")
    for row in res.tokens:
        print("  ", row.tolist())


if __name__ == "__main__":
    main()
