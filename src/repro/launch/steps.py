"""Step functions lowered by the dry-run / executed by train.py & serve.py."""
from __future__ import annotations

import jax

from repro.config import ModelConfig
from repro.models import transformer as T
from repro.training import optim


def make_train_step(cfg: ModelConfig, opt_cfg: optim.OptimConfig,
                    *, mode: str = "flash", moe_dispatch: str = "einsum",
                    remat: bool = True):
    def train_step(params, opt_state, batch):
        def lf(p):
            return T.loss_fn(p, cfg, batch, mode=mode,
                             moe_dispatch=moe_dispatch, remat=remat)
        (_, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        params, opt_state, om = optim.adamw_update(params, grads, opt_state,
                                                   opt_cfg)
        return params, opt_state, {**metrics, **om}
    return train_step


def make_prefill_step(cfg: ModelConfig, *, mode: str = "flash",
                      moe_dispatch: str = "einsum"):
    def prefill_step(params, batch):
        return T.prefill(params, cfg, batch, mode=mode,
                         moe_dispatch=moe_dispatch)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens, pos):
        return T.decode_step(params, cfg, cache, tokens, pos)
    return serve_step
