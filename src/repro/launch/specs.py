"""ShapeDtypeStruct input stand-ins for every (arch x input-shape) pair —
weak-type-correct, shardable, no device allocation."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ShapeSpec, INPUT_SHAPES
from repro.models import transformer as T

SDS = jax.ShapeDtypeStruct


def variant_for_shape(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """long_500k needs sub-quadratic attention: SSM/hybrid run natively;
    quadratic-attention archs get the sliding-window variant (window 4096,
    ring-buffer cache).  See DESIGN.md §6."""
    if (shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm")
            and not cfg.sliding_window):
        return cfg.with_(sliding_window=4096)
    return cfg


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Model inputs for a full-sequence step (train / prefill)."""
    B, S = shape.global_batch, shape.seq_len
    act = jnp.dtype(cfg.activation_dtype)
    if cfg.family == "vlm":
        s_text = S - cfg.n_patches
        return {
            "tokens": SDS((B, s_text), jnp.int32),
            "patch_embeds": SDS((B, cfg.n_patches, cfg.d_model), act),
        }
    if cfg.family == "audio":
        return {
            "tokens": SDS((B, S), jnp.int32),
            "audio_frames": SDS((B, cfg.n_audio_frames, cfg.d_model), act),
        }
    return {"tokens": SDS((B, S), jnp.int32)}


def decode_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Inputs for serve_step: one new token against a seq_len KV cache."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: T.init_cache(cfg, B, S))
    return {
        "tokens": SDS((B, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
        "cache": cache,
    }


def params_specs(cfg: ModelConfig, max_seq: int):
    return jax.eval_shape(
        lambda k: T.init_params(k, cfg, max_seq=max_seq),
        SDS((2,), np.uint32))


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """All ShapeDtypeStruct inputs for the step this shape lowers."""
    if shape.kind == "decode":
        return decode_specs(cfg, shape)
    return batch_specs(cfg, shape)
