"""Sharding rules: map parameter / cache / batch pytrees to NamedShardings.

Strategy (DESIGN.md §4):
  * tensor parallel over "model": attention heads, FFN hidden, vocab,
    experts;
  * FSDP over "data" (+"pod"): the non-TP dimension of every matmul
    weight, gathered per-layer inside the scan by GSPMD;
  * batch over ("pod", "data");
  * KV caches: kv-heads over "model" when divisible, else cache sequence
    over "model" (MQA archs — flash-decoding-style partial softmax).

All assignments are divisibility-aware (models.pspec): a rule that does
not divide a concrete dim falls back to replication for that dim.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig
from repro.models.pspec import pspec_for, mesh_rules, set_mesh_rules

# Sharding presets (hillclimbed in EXPERIMENTS.md §Perf):
#   baseline  — TP over "model" + FSDP over "data", batch over (pod, data)
#   dp        — pure data parallel: batch over EVERY axis, params FSDP over
#               "data" only.  Right for small models whose head counts do
#               not divide the model axis (smollm 15H, qwen1.5 20H): the
#               baseline replicates their attention 16x over "model".
#   infer-tp  — serving: params TP over "model", REPLICATED over "data"
#               (no per-step FSDP all-gathers), batch over (pod, data).
SHARDING_PRESETS = {
    "baseline": None,
    "dp": {
        "batch": ("pod", "data", "model"),
        "fsdp": ("data",),
        "model": (),
        "expert": ("model",),
        "seq": (),
    },
    "infer-tp": {
        "batch": ("pod", "data"),
        "fsdp": (),
        "model": ("model",),
        "expert": ("model",),
        "seq": ("model",),
    },
    # true expert parallelism: one expert per chip (256 experts over
    # data x model = 256); token all-to-all replaces per-layer expert
    # weight all-gathers.  Non-expert params keep baseline TP+FSDP.
    "ep": {
        "batch": ("pod", "data"),
        "fsdp": ("data",),
        "model": ("model",),
        "expert": ("data", "model"),
        "seq": ("model",),
    },
    # serving for giant MoE: 256-way tensor parallel — weights sharded
    # over BOTH axes and never gathered; small per-layer activation
    # all-reduces replace per-step FSDP weight all-gathers.
    "infer-tp2": {
        "batch": ("pod",),
        "fsdp": (),
        "model": ("data", "model"),
        "expert": ("data", "model"),
        "seq": (),
    },
}

# The continuous engine's mesh (launch.mesh.make_serving_mesh): params
# tensor-parallel over "model", replicated elsewhere (no FSDP — serving
# never pays per-step weight all-gathers), experts expert-parallel over
# "model".  "batch" and "seq" stay REPLICATED: slots are few, decode
# scatters index the paged pool per batch row, and the page axis carries
# block-table semantics no mesh axis may cut.
SERVING_LOGICAL_MAP = {
    "batch": (),
    "fsdp": (),
    "model": ("model",),
    "expert": ("model",),
    "seq": (),
}

# weights whose LAST dim is the contraction output fed back to d_model
_DOWN_STYLE = ("w_o", "w_down", "out_proj")
_REPLICATED = ("A_log", "D", "dt_bias", "b_if", "b_gates", "conv_w", "conv_b",
               "scale", "bias", "b_q", "b_k", "b_v", "b_up", "b_down",
               "router", "skip", "r_gates")


def _path_names(path) -> list:
    out = []
    for e in path:
        if hasattr(e, "key"):
            out.append(str(e.key))
        elif hasattr(e, "name"):
            out.append(str(e.name))
    return out


def param_logical_axes(path, leaf) -> list:
    """Return the logical axis names for one parameter leaf."""
    names = _path_names(path)
    last = names[-1] if names else ""
    nd = leaf.ndim
    if last in _REPLICATED or nd <= 1:
        return [None] * nd
    if last == "embed":
        return [None] * (nd - 2) + ["model", "fsdp"]      # (vocab, d)
    if last in ("lm_head",):
        return [None] * (nd - 2) + ["fsdp", "model"]      # (d, vocab)
    if last == "dec_pos":
        return [None] * nd
    in_moe = "moe" in names and last in ("w_gate", "w_up", "w_down")
    if in_moe:
        # stacked (L, E, d, f) or (E, d, f).  When the "expert" logical
        # axis maps onto the axes fsdp would use (the "ep" preset),
        # pspec_for's duplicate guard drops the fsdp entry automatically.
        core = (["expert", None, "fsdp"] if last == "w_down"
                else ["expert", "fsdp", None])
        return [None] * (nd - 3) + core
    if last in _DOWN_STYLE:
        return [None] * (nd - 2) + ["model", "fsdp"]
    # generic "up-style" matmul weight (d_in, d_out)
    return [None] * (nd - 2) + ["fsdp", "model"]


def params_pspecs(mesh: Mesh, params_shape, logical_map=None) -> object:
    """NamedSharding tree for a params pytree of ShapeDtypeStructs."""
    with mesh_rules(mesh, logical_map):
        def one(path, leaf):
            spec = pspec_for(leaf.shape, param_logical_axes(path, leaf))
            return NamedSharding(mesh, spec if spec is not None else P())
        return jax.tree_util.tree_map_with_path(one, params_shape)


def cache_logical_axes(cfg: ModelConfig, path, leaf) -> list:
    names = _path_names(path)
    last = names[-1]
    nd = leaf.ndim
    model_divides_kv = cfg.n_kv_heads and cfg.n_kv_heads % 16 == 0
    if last in ("k", "v", "xk", "xv"):
        # (L, B, S, Hkv, D)
        if model_divides_kv:
            return [None, "batch", None, "model", None]
        return [None, "batch", "seq", None, None]
    if last in ("ckv", "krope"):
        # (L, B, S, rank) — shard the latent rank over model (krope's 64
        # rank falls back to replication automatically if indivisible)
        return [None, "batch", None, "model"]
    if last == "ssm":
        # (..., B, H, P, N)
        return [None] * (nd - 4) + ["batch", "model", None, None]
    if last == "conv":
        return [None] * (nd - 3) + ["batch", None, "model"]
    if last == "C":
        # mLSTM matrix memory (..., B, H, dqk, dv)
        return [None] * (nd - 4) + ["batch", None, "model", None]
    if last in ("n",):
        return [None] * (nd - 3) + ["batch", None, "model"]
    if last in ("m", "h"):
        return [None] * (nd - 2) + ["batch", None]
    if last == "c":
        return [None] * (nd - 3) + ["batch", None, None]
    if last == "conv_win":
        return [None] * (nd - 3) + ["batch", None, None]
    return [None] * nd


def cache_pspecs(mesh: Mesh, cfg: ModelConfig, cache_shape, logical_map=None):
    with mesh_rules(mesh, logical_map):
        def one(path, leaf):
            spec = pspec_for(leaf.shape, cache_logical_axes(cfg, path, leaf))
            return NamedSharding(mesh, spec if spec is not None else P())
        return jax.tree_util.tree_map_with_path(one, cache_shape)


def paged_cache_logical_axes(cfg: ModelConfig, path, leaf) -> list:
    """Logical axes for one PAGED KV pool leaf (``init_paged_cache``):
    k/v pools (L, n_pages, page_size, Hkv, hd) shard their KV heads over
    "model"; MLA latent pools (L, n_pages, page_size, rank) shard the
    latent rank.  The layer/page/offset axes are never cut — a page is
    whole on every device along them, so extract/graft snapshots (and
    hence spills, checkpoints and constellation handovers) reassemble
    token-exactly from a plain ``device_get``.  Indivisible head counts
    fall back to replication through ``pspec_for``."""
    last = _path_names(path)[-1]
    nd = leaf.ndim
    if last in ("k", "v"):
        return [None, None, None, "model", None]
    if last in ("ckv", "krope"):
        return [None, None, None, "model"]
    return [None] * nd


def paged_cache_pspecs(mesh: Mesh, cfg: ModelConfig, cache_shape,
                       logical_map=None):
    """NamedSharding tree for an ``init_paged_cache`` pool."""
    with mesh_rules(mesh, logical_map):
        def one(path, leaf):
            spec = pspec_for(leaf.shape,
                             paged_cache_logical_axes(cfg, path, leaf))
            return NamedSharding(mesh, spec if spec is not None else P())
        return jax.tree_util.tree_map_with_path(one, cache_shape)


def batch_pspecs(mesh: Mesh, batch_shape, logical_map=None):
    """Shard every batch input over the "batch" logical axes on dim 0."""
    with mesh_rules(mesh, logical_map):
        def one(leaf):
            spec = pspec_for(leaf.shape,
                             ["batch"] + [None] * (leaf.ndim - 1))
            return NamedSharding(mesh, spec if spec is not None else P())
        return jax.tree.map(one, batch_shape)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
