"""Production mesh definitions (TPU v5e target).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run forces 512 host devices; tests and
benches must keep seeing 1).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh for tests/examples on CPU."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_serving_mesh(n_devices=None):
    """Serving mesh: every local device on the tensor-parallel "model"
    axis (a trivial "data" axis keeps the logical-axis maps and preset
    rules shared with training).  ``ContinuousEngine(mesh=...)`` shards
    attention heads, the paged KV pool and MoE experts over it; on CPU
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` forces a
    4-device host platform, which is how the sharded serving tests and
    bench lane run without accelerators."""
    if n_devices is None:
        n_devices = len(jax.devices())
    return jax.make_mesh((1, n_devices), ("data", "model"))


# TPU v5e hardware constants for the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link
