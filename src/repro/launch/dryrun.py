import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes with 512 placeholder host devices, and extract
memory / cost / collective statistics for EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
        --shape train_4k [--multi-pod] [--mode triangular] \
        [--moe-dispatch scatter] [--json out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all --json results/
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import numpy as np

from repro.analysis.hlo import analyze_hlo
from repro.config import (ARCH_IDS, INPUT_SHAPES, get_config,
                          supports_shape)
from repro.launch import sharding as SH
from repro.launch import specs as SP
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.models.pspec import set_mesh_rules
from repro.training import optim



def _moment_dtype(cfg) -> str:
    # deepseek-scale optimizer state cannot hold fp32 moments on a 256-chip
    # v5e pod; use bf16 moments for >=100B-param configs (DESIGN.md §4)
    return "bfloat16" if cfg.param_count() > 100e9 else "float32"


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               mode: str = "flash", moe_dispatch: str = "einsum",
               window_override: int | None = None,
               sharding: str = "baseline", remat: bool = True,
               save_hlo: str | None = None,
               verbose: bool = True) -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg = SP.variant_for_shape(get_config(arch), shape)
    if window_override is not None:
        cfg = cfg.with_(sliding_window=window_override)
    if not supports_shape(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "unsupported pair (DESIGN.md §6)"}

    lmap = SH.SHARDING_PRESETS[sharding]
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_mesh_rules(mesh, lmap)
    t0 = time.time()

    params_sh = SP.params_specs(cfg, max_seq=shape.seq_len)
    p_spec = SH.params_pspecs(mesh, params_sh, lmap)
    rep = SH.replicated(mesh)

    if shape.kind == "train":
        opt_cfg = optim.OptimConfig(moment_dtype=_moment_dtype(cfg))
        opt_sh = jax.eval_shape(lambda p: optim.adamw_init(p, opt_cfg),
                                params_sh)
        o_spec = {"mu": p_spec, "nu": p_spec, "step": rep}
        batch_sh = SP.batch_specs(cfg, shape)
        b_spec = SH.batch_pspecs(mesh, batch_sh, lmap)
        fn = ST.make_train_step(cfg, opt_cfg, mode=mode,
                                moe_dispatch=moe_dispatch, remat=remat)
        jitted = jax.jit(fn, in_shardings=(p_spec, o_spec, b_spec),
                         out_shardings=(p_spec, o_spec, rep),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_sh, opt_sh, batch_sh)
    elif shape.kind == "prefill":
        batch_sh = SP.batch_specs(cfg, shape)
        b_spec = SH.batch_pspecs(mesh, batch_sh, lmap)
        fn = ST.make_prefill_step(cfg, mode=mode, moe_dispatch=moe_dispatch)
        jitted = jax.jit(fn, in_shardings=(p_spec, b_spec))
        lowered = jitted.lower(params_sh, batch_sh)
    else:  # decode
        d = SP.decode_specs(cfg, shape)
        c_spec = SH.cache_pspecs(mesh, cfg, d["cache"], lmap)
        t_spec = SH.batch_pspecs(mesh, {"tokens": d["tokens"]}, lmap)["tokens"]
        fn = ST.make_serve_step(cfg)
        jitted = jax.jit(fn, in_shardings=(p_spec, c_spec, t_spec, rep),
                         out_shardings=(None, c_spec), donate_argnums=(1,))
        lowered = jitted.lower(params_sh, d["cache"], d["tokens"], d["pos"])

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    # trip-count-aware accounting (repro.analysis.hlo); the raw
    # cost_analysis numbers are kept for comparison — XLA counts while
    # bodies once, so they undercount scanned-layer models ~n_layers x.
    hlo_text = compiled.as_text()
    if save_hlo:
        import zstandard as zstd
        with open(save_hlo, "wb") as f:
            f.write(zstd.ZstdCompressor(level=3).compress(hlo_text.encode()))
    hlo = analyze_hlo(hlo_text)

    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.devices.size,
        "kind": shape.kind, "mode": mode, "moe_dispatch": moe_dispatch,
        "sharding": sharding,
        "sliding_window": cfg.sliding_window,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": hlo["flops"],
        "bytes_per_device": hlo["bytes"],
        "collectives": {**hlo["coll"],
                        "total_link_bytes": hlo["total_link_bytes"]},
        "xla_cost_analysis": {
            "flops": float(cost.get("flops", -1)),
            "bytes_accessed": float(cost.get("bytes accessed", -1)),
        },
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem,
                                            "generated_code_size_in_bytes",
                                            None),
        },
        "params_total": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
    }
    if verbose:
        print(json.dumps(res, indent=2))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="flash",
                    choices=["flash", "naive"])
    ap.add_argument("--moe-dispatch", default="einsum",
                    choices=["einsum", "scatter"])
    ap.add_argument("--sharding", default="baseline",
                    choices=list(SH.SHARDING_PRESETS))
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--window", type=int, default=None)
    ap.add_argument("--json", default=None,
                    help="output file (single) or directory (--all)")
    args = ap.parse_args()

    if args.all:
        assert args.json, "--all requires --json DIR"
        os.makedirs(args.json, exist_ok=True)
        failures = []
        for arch in ARCH_IDS:
            for shape in INPUT_SHAPES:
                tag = f"{arch}__{shape}__{'multi' if args.multi_pod else 'single'}"
                out = os.path.join(args.json, tag + ".json")
                if os.path.exists(out):
                    print("skip (exists):", tag)
                    continue
                print("=== ", tag, flush=True)
                try:
                    res = dryrun_one(arch, shape, multi_pod=args.multi_pod,
                                     mode=args.mode,
                                     moe_dispatch=args.moe_dispatch,
                                     save_hlo=out.replace(".json", ".hlo.zst"),
                                     verbose=False)
                except Exception as e:
                    traceback.print_exc()
                    failures.append(tag)
                    res = {"arch": arch, "shape": shape, "error": str(e)[:2000]}
                with open(out, "w") as f:
                    json.dump(res, f, indent=2)
        print("FAILURES:", failures)
        sys.exit(1 if failures else 0)
    else:
        res = dryrun_one(args.arch, args.shape, multi_pod=args.multi_pod,
                         mode=args.mode, moe_dispatch=args.moe_dispatch,
                         sharding=args.sharding, remat=not args.no_remat,
                         window_override=args.window)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(res, f, indent=2)


if __name__ == "__main__":
    main()
