"""Flash attention in pure JAX with a flash backward (custom_vjp).

The naive softmax(QK^T)V under autodiff saves the O(S^2) probability
tensor as a residual — at 32k context that is gigabytes per layer per
device and dominates both HBM traffic and live memory (it would not fit
the 16 GB v5e target at all).  This module implements the
FlashAttention-2 scheme in plain jnp:

  * forward: python-unrolled query blocks; per block a lax.scan over key
    blocks with online softmax.  Causal block skipping is STATIC (query
    block i only visits key blocks <= i), so causal attention costs
    ~S^2/2 + diagonal, not S^2.
  * residuals: (q, k, v, out, lse) — O(S*D), no probability tensor.
  * backward: one lax.scan over key blocks with an inner scan over query
    blocks, recomputing probabilities from the stored LSE.  dQ
    accumulates via dynamic-update-slice-add into the outer carry.

``repro.kernels.flash_attention`` is the Pallas/TPU twin of the forward
pass; this is the lowering used by the dry-run (Mosaic cannot compile on
the CPU host platform) and the oracle the kernel is tested against.

Layout: grouped GQA — q: (B, Sq, Hkv, g, D); k/v: (B, Skv, Hkv, D).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = -1e30


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad_seq(x, target: int):
    if x.shape[1] == target:
        return x
    pad = [(0, 0)] * x.ndim
    pad[1] = (0, target - x.shape[1])
    return jnp.pad(x, pad)


def _block_mask(qpos, kpos, causal, window, kv_limit):
    m = (kpos[None, :] < kv_limit)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window:
        m &= (qpos[:, None] - kpos[None, :]) < window
    return m


# ==========================================================================
# forward
# ==========================================================================

def _fwd_qblock(cfg, qb, k, v, i, Skv_real, kv_len):
    """One query block against its (statically skipped) key range."""
    causal, q_offset, window, bq, bk = cfg
    B, bq_, Hkv, g, D = qb.shape
    Skv_pad = k.shape[1]
    q_lo = q_offset + i * bq
    q_hi = q_offset + (i + 1) * bq
    hi = min(Skv_pad, _ceil_to(min(q_hi, Skv_real) if causal else Skv_real, bk))
    lo = 0
    if window:
        lo = max(0, (q_lo + 1 - window) // bk * bk)
    hi = max(hi, lo + bk)
    nkb = (hi - lo) // bk

    kseg = jax.lax.slice_in_dim(k, lo, hi, axis=1)
    vseg = jax.lax.slice_in_dim(v, lo, hi, axis=1)
    kb = kseg.reshape(B, nkb, bk, Hkv, D).swapaxes(0, 1)
    vb = vseg.reshape(B, nkb, bk, Hkv, vseg.shape[-1]).swapaxes(0, 1)
    qpos = q_lo + jnp.arange(bq)
    scale = D ** -0.5
    qf = qb.astype(F32) * scale
    kv_limit = jnp.minimum(kv_len, Skv_real)

    def body(carry, inp):
        acc, m, l = carry
        kbj, vbj, j = inp
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kbj.astype(F32))
        kpos = lo + j * bk + jnp.arange(bk)
        msk = _block_mask(qpos, kpos, causal, window, kv_limit)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = (acc * corr[..., None]
                   + jnp.einsum("bhgqk,bkhd->bhgqd", p, vbj.astype(F32)))
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Hkv, g, bq, v.shape[-1]), F32)
    m0 = jnp.full((B, Hkv, g, bq), NEG_INF, F32)
    l0 = jnp.zeros((B, Hkv, g, bq), F32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                  (kb, vb, jnp.arange(nkb)))
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).transpose(0, 3, 1, 2, 4)  # (B,bq,Hkv,g,Dv)
    lse = m + jnp.log(l_safe)                                  # (B,Hkv,g,bq)
    return out, lse


def _flash_fwd_impl(cfg, q, k, v, kv_len):
    causal, q_offset, window, bq, bk = cfg
    B, Sq, Hkv, g, D = q.shape
    Skv_real = k.shape[1]
    Skv_pad = _ceil_to(Skv_real, bk)
    k = _pad_seq(k, Skv_pad)
    v = _pad_seq(v, Skv_pad)
    Sq_pad = _ceil_to(Sq, bq)
    qp = _pad_seq(q, Sq_pad)
    outs, lses = [], []
    for i in range(Sq_pad // bq):
        ob, lseb = _fwd_qblock(cfg, qp[:, i * bq:(i + 1) * bq], k, v, i,
                               Skv_real, kv_len)
        outs.append(ob)
        lses.append(lseb)
    out = jnp.concatenate(outs, axis=1)[:, :Sq]
    lse = jnp.concatenate(lses, axis=-1)[..., :Sq]             # (B,Hkv,g,Sq)
    return out.astype(q.dtype), lse


# ==========================================================================
# backward
# ==========================================================================

def _flash_bwd_impl(cfg, q, k, v, out, lse, dout):
    causal, q_offset, window, bq, bk = cfg
    B, Sq, Hkv, g, D = q.shape
    Dv = v.shape[-1]
    Skv_real = k.shape[1]
    Skv_pad = _ceil_to(Skv_real, bk)
    Sq_pad = _ceil_to(Sq, bq)
    kp = _pad_seq(k, Skv_pad).astype(F32)
    vp = _pad_seq(v, Skv_pad).astype(F32)
    scale = D ** -0.5
    qp = _pad_seq(q, Sq_pad).astype(F32) * scale
    dop = _pad_seq(dout, Sq_pad).astype(F32)
    lsep = jnp.pad(lse, [(0, 0)] * 3 + [(0, Sq_pad - Sq)],
                   constant_values=0.0)
    # delta_i = rowsum(dO_i * O_i)
    delta = jnp.sum(dop * _pad_seq(out, Sq_pad).astype(F32), axis=-1)
    delta = delta.transpose(0, 2, 3, 1)                        # (B,Hkv,g,Sq)

    nqb = Sq_pad // bq
    nkb = Skv_pad // bk
    qb = qp.reshape(B, nqb, bq, Hkv, g, D).swapaxes(0, 1)
    dob = dop.reshape(B, nqb, bq, Hkv, g, Dv).swapaxes(0, 1)
    lseb = lsep.reshape(B, Hkv, g, nqb, bq).transpose(3, 0, 1, 2, 4)
    deltab = delta.reshape(B, Hkv, g, nqb, bq).transpose(3, 0, 1, 2, 4)
    kb = kp.reshape(B, nkb, bk, Hkv, D).swapaxes(0, 1)
    vb = vp.reshape(B, nkb, bk, Hkv, Dv).swapaxes(0, 1)

    def kv_block(dq_acc, inp):
        kbj, vbj, j = inp
        kpos = j * bk + jnp.arange(bk)

        def q_block(carry, qinp):
            dkj, dvj, dq_acc = carry
            qbi, dobi, lsei, deli, i = qinp
            qpos = q_offset + i * bq + jnp.arange(bq)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qbi, kbj)
            msk = _block_mask(qpos, kpos, causal, window, Skv_real)
            p = jnp.where(msk[None, None, None],
                          jnp.exp(s - lsei[..., None]), 0.0)
            dvj = dvj + jnp.einsum("bhgqk,bqhgd->bkhd", p, dobi)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", dobi, vbj)
            ds = p * (dp - deli[..., None])
            # qbi carries the softmax scale, so ds^T.qbi == ds^T.q * scale
            dkj = dkj + jnp.einsum("bhgqk,bqhgd->bkhd", ds, qbi)
            dqi = jnp.einsum("bhgqk,bkhd->bqhgd", ds, kbj) * scale
            dq_acc = jax.lax.dynamic_update_slice_in_dim(
                dq_acc,
                jax.lax.dynamic_slice_in_dim(dq_acc, i * bq, bq, 1) + dqi,
                i * bq, axis=1)
            return (dkj, dvj, dq_acc), None

        dk0 = jnp.zeros((B, bk, Hkv, D), F32)
        dv0 = jnp.zeros((B, bk, Hkv, Dv), F32)
        (dkj, dvj, dq_acc), _ = jax.lax.scan(
            q_block, (dk0, dv0, dq_acc),
            (qb, dob, lseb, deltab, jnp.arange(nqb)))
        return dq_acc, (dkj, dvj)

    dq0 = jnp.zeros((B, Sq_pad, Hkv, g, D), F32)
    dq, (dks, dvs) = jax.lax.scan(kv_block, dq0, (kb, vb, jnp.arange(nkb)))
    dk = dks.swapaxes(0, 1).reshape(B, Skv_pad, Hkv, D)[:, :Skv_real]
    dv = dvs.swapaxes(0, 1).reshape(B, Skv_pad, Hkv, Dv)[:, :Skv_real]
    dq = dq[:, :Sq]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


# ==========================================================================
# public API
# ==========================================================================

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash(cfg, q, k, v):
    out, _ = _flash_fwd_impl(cfg, q, k, v, jnp.int32(k.shape[1]))
    return out


def _flash_fwd_rule(cfg, q, k, v):
    out, lse = _flash_fwd_impl(cfg, q, k, v, jnp.int32(k.shape[1]))
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(cfg, res, dout):
    q, k, v, out, lse = res
    return _flash_bwd_impl(cfg, q, k, v, out, lse, dout)


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                    window: int = 0, kv_len: Optional[jax.Array] = None,
                    block_q: int = 1024, block_k: int = 1024) -> jax.Array:
    """Grouped-GQA flash attention.

    q: (B, Sq, H, D); k, v: (B, Skv, Hkv, D).  When ``kv_len`` is given
    (decode against a partially filled cache) the non-vjp path is used —
    no gradients flow through serving.
    """
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Sq, Hkv, g, D)
    bq = min(block_q, _ceil_to(Sq, 128))
    bk = min(block_k, _ceil_to(k.shape[1], 128))
    cfg = (causal, q_offset, window, bq, bk)
    if kv_len is None:
        out = _flash(cfg, qg, k, v)
    else:
        out, _ = _flash_fwd_impl(cfg, qg, k, v, kv_len)
    return out.reshape(B, Sq, H, -1)
