"""Mamba2 (SSD) block — chunked selective-scan in pure JAX.

State-space recurrence per head h with state size N and head dim P:
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * (B_t ⊗ x_t)        (P, N)
    y_t = C_t · h_t + D * x_t
computed chunk-parallel (SSD algorithm [arXiv:2405.21060]): quadratic
attention-like form within chunks, a sequential scan across chunk
states.  ``repro.kernels.ssm_scan`` is the Pallas TPU version of the
intra-chunk part; this module is also its oracle's substrate.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models.pspec import shard


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads


def init_mamba2(key, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    dt = L.dtype_of(cfg.param_dtype)
    d = cfg.d_model
    d_inner, nh = _dims(cfg)
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 4)
    # dt bias: inverse-softplus of dt ~ U[1e-3, 1e-1]
    u = jax.random.uniform(ks[2], (nh,), jnp.float32, 1e-3, 1e-1)
    dt_bias = u + jnp.log(-jnp.expm1(-u))
    return {
        "in_proj": L.dense_init(
            ks[0], (d, 2 * d_inner + 2 * s.n_groups * s.d_state + nh), dt),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_ch), jnp.float32)
                   * (1.0 / s.d_conv ** 0.5)).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jax.random.uniform(ks[3], (nh,), jnp.float32, 1.0, 16.0)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias,
        "norm": L.init_rmsnorm(d_inner, dt),
        "out_proj": L.dense_init(ks[0], (d_inner, d), dt),
    }


def _split_proj(p, cfg, x):
    s = cfg.ssm
    d_inner, nh = _dims(cfg)
    gn = s.n_groups * s.d_state
    zxbcdt = x @ p["in_proj"]
    z, xin, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + gn, 2 * d_inner + 2 * gn],
        axis=-1)
    return z, xin, Bm, Cm, dt


def causal_conv(x, w, b):
    """Depthwise causal conv.  x: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        out = out + xp[:, i:i + x.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int,
                h0: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    xh: (B,S,H,P)  dt: (B,S,H) (post-softplus)  A: (H,) negative
    Bm, Cm: (B,S,H,N)  (already broadcast from groups to heads)
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bt, S, H, P = xh.shape
    N = Bm.shape[-1]
    Lc = min(chunk, S)
    assert S % Lc == 0, (S, Lc)
    nc = S // Lc

    f32 = jnp.float32
    xc = xh.reshape(Bt, nc, Lc, H, P).astype(f32)
    dtc = dt.reshape(Bt, nc, Lc, H).astype(f32)
    Bc = Bm.reshape(Bt, nc, Lc, H, N).astype(f32)
    Cc = Cm.reshape(Bt, nc, Lc, H, N).astype(f32)

    loga = dtc * A[None, None, None, :]                  # (B,nc,Lc,H) <= 0
    cum = jnp.cumsum(loga, axis=2)                       # l_t

    # intra-chunk quadratic form; decay[t,s] = l_t - l_s
    Smat = jnp.einsum("bclhn,bcshn->bchls", Cc, Bc)      # (B,nc,H,Lc,Lc)
    lt = cum.transpose(0, 1, 3, 2)                       # (B,nc,H,Lc)
    decay = lt[..., :, None] - lt[..., None, :]          # (B,nc,H,Lc,Lc)
    tri = jnp.tril(jnp.ones((Lc, Lc), bool))
    W = jnp.where(tri, Smat * jnp.exp(decay), 0.0)
    W = W * dtc.transpose(0, 1, 3, 2)[..., None, :]      # * dt_s
    y_intra = jnp.einsum("bchls,bcshp->bclhp", W, xc)

    # per-chunk end state:  sum_s exp(l_L - l_s) dt_s B_s (x) x_s
    wS = jnp.exp(lt[..., -1:] - lt) * dtc.transpose(0, 1, 3, 2)   # (B,nc,H,Lc)
    hc = jnp.einsum("bchs,bcshn,bcshp->bchpn", wS, Bc, xc)

    # inter-chunk sequential scan
    chunk_decay = jnp.exp(lt[..., -1])                   # (B,nc,H)
    h_init = (jnp.zeros((Bt, H, P, N), f32) if h0 is None
              else h0.astype(f32))

    def body(h, inp):
        dec, hck = inp                                    # (B,H), (B,H,P,N)
        h_new = h * dec[..., None, None] + hck
        return h_new, h

    final, h_prevs = jax.lax.scan(
        body, h_init, (chunk_decay.swapaxes(0, 1), hc.swapaxes(0, 1)))
    h_prevs = h_prevs.swapaxes(0, 1)           # (B,nc,H,P,N) state before chunk

    y_inter = jnp.einsum("bclhn,bchpn->bclhp",
                         Cc * jnp.exp(cum)[..., None], h_prevs)
    y = (y_intra + y_inter).reshape(Bt, S, H, P)
    return y, final


def mamba2_fwd(p: dict, cfg: ModelConfig, x, *, return_state: bool = False):
    """Full-sequence Mamba2 block.  x: (B, S, d)."""
    s = cfg.ssm
    d_inner, nh = _dims(cfg)
    B, S, _ = x.shape
    z, xin, Bm, Cm, dt = _split_proj(p, cfg, x)
    xbc_pre = jnp.concatenate([xin, Bm, Cm], axis=-1)   # pre-conv (cached)
    xbc = jax.nn.silu(causal_conv(xbc_pre, p["conv_w"], p["conv_b"]).astype(jnp.float32)).astype(x.dtype)
    xin, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + s.n_groups * s.d_state], axis=-1)

    xh = xin.reshape(B, S, nh, s.head_dim)
    xh = shard(xh, "batch", None, "model", None)
    rep = nh // s.n_groups
    Bh = jnp.repeat(Bm.reshape(B, S, s.n_groups, s.d_state), rep, axis=2)
    Ch = jnp.repeat(Cm.reshape(B, S, s.n_groups, s.d_state), rep, axis=2)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y, state = ssd_chunked(xh, dtv, A, Bh, Ch, s.chunk)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.astype(x.dtype).reshape(B, S, d_inner)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                  cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        return out, {"ssm": state, "conv": xbc_pre[:, -(s.d_conv - 1):]}
    return out


def mamba2_decode(p: dict, cfg: ModelConfig, x, cache: dict):
    """Single-token recurrent step.  x: (B, 1, d).
    cache: {"ssm": (B,H,P,N) f32, "conv": (B, d_conv-1, conv_ch)}."""
    s = cfg.ssm
    d_inner, nh = _dims(cfg)
    B = x.shape[0]
    z, xin, Bm, Cm, dt = _split_proj(p, cfg, x)
    xbc = jnp.concatenate([xin, Bm, Cm], axis=-1)        # (B,1,conv_ch)
    win = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B,d_conv,ch)
    conv_out = (jnp.einsum("bkc,kc->bc", win.astype(jnp.float32),
                           p["conv_w"].astype(jnp.float32))
                + p["conv_b"].astype(jnp.float32))
    xbc = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)
    new_conv = win[:, 1:]
    xin2, Bm2, Cm2 = jnp.split(
        xbc, [d_inner, d_inner + s.n_groups * s.d_state], axis=-1)

    xh = xin2.reshape(B, nh, s.head_dim).astype(jnp.float32)
    rep = nh // s.n_groups
    Bh = jnp.repeat(Bm2.reshape(B, s.n_groups, s.d_state), rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cm2.reshape(B, s.n_groups, s.d_state), rep, axis=1).astype(jnp.float32)
    dtv = jax.nn.softplus(dt.astype(jnp.float32)[:, 0] + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    h = cache["ssm"].astype(jnp.float32)
    decay = jnp.exp(dtv * A)                              # (B,H)
    h = (h * decay[..., None, None]
         + jnp.einsum("bh,bhn,bhp->bhpn", dtv, Bh, xh))
    y = jnp.einsum("bhn,bhpn->bhp", Ch, h) + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                  cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, {"ssm": h, "conv": new_conv}
