"""Model assembly for every assigned architecture family.

One functional API across dense / moe / hybrid / ssm / audio / vlm:

    params            = init_params(key, cfg, max_seq)
    logits, aux       = forward(params, cfg, batch)            # train/prefill
    logits, aux, cache= forward(..., return_cache=True)        # prefill
    cache             = init_cache(cfg, batch, max_seq)
    logits, cache     = decode_step(params, cfg, cache, tok, pos)

Layers are **scanned** (stacked params) to keep compile time and HLO size
tractable at 48–88 layers; heterogeneous archs scan over repeat units
(zamba2: 6 mamba + 1 shared attn; xlstm: 7 mLSTM + 1 sLSTM).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import xlstm as X
from repro.models.pspec import shard

F32 = jnp.float32


# ==========================================================================
# per-block init / fwd / decode
# ==========================================================================

def _init_attn_block(key, cfg: ModelConfig, use_moe: bool,
                     dense_ff: Optional[int] = None, gelu: bool = False,
                     cross: bool = False, d_in: Optional[int] = None) -> dict:
    dt = L.dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    ln = cfg.is_encoder_decoder            # whisper uses LayerNorm w/ bias
    p = {"ln1": L.init_norm(d_in or cfg.d_model, dt, ln)}
    if cfg.mla is not None:
        p["attn"] = A.init_mla(ks[0], cfg)
    else:
        p["attn"] = A.init_attention(ks[0], cfg, d_in=d_in)
    if cross:
        p["ln_x"] = L.init_norm(cfg.d_model, dt, ln)
        p["xattn"] = A.init_attention(ks[3], cfg)
    p["ln2"] = L.init_norm(cfg.d_model, dt, ln)
    gelu = gelu or cfg.mlp_type == "gelu"
    if use_moe:
        p["moe"] = M.init_moe(ks[1], cfg)
    elif gelu:
        p["mlp"] = L.init_gelu_mlp(ks[2], cfg.d_model, dense_ff or cfg.d_ff, dt)
    else:
        p["mlp"] = L.init_swiglu(ks[2], cfg.d_model, dense_ff or cfg.d_ff, dt)
    return p


def _attn_block_fwd(p, cfg, x, positions, *, causal=True, window=0,
                    mode="flash", moe_dispatch="einsum", moe_drop_free=False,
                    moe_capacity=None, rope=True, enc_out=None,
                    return_kv=False, x_extra=None):
    """Pre-norm residual block.  Returns (x, aux, kv or None)."""
    eps = cfg.norm_eps
    if x_extra is not None:                    # zamba2 shared block: concat
        h_in = L.norm(p["ln1"], jnp.concatenate([x, x_extra], axis=-1), eps)
    else:
        h_in = L.norm(p["ln1"], x, eps)
    kv = None
    if cfg.mla is not None:
        if return_kv:
            a, kv = A.mla_fwd(p["attn"], cfg, h_in, positions, mode=mode,
                              return_cache=True)
        else:
            a = A.mla_fwd(p["attn"], cfg, h_in, positions, mode=mode)
    else:
        r = A.attention_fwd(p["attn"], cfg, h_in, positions, causal=causal,
                            window=window, mode=mode, rope=rope,
                            return_kv=return_kv)
        a, kv = r if return_kv else (r, None)
    x = x + a
    if enc_out is not None:                    # whisper cross-attention
        cx = A.attention_fwd(p["xattn"], cfg, L.norm(p["ln_x"], x, eps),
                             positions, causal=False, rope=False,
                             xkv=enc_out, return_kv=return_kv)
        ca, ckv = cx if return_kv else (cx, None)
        x = x + ca
        kv = (kv, ckv) if return_kv else None
    aux = jnp.zeros((), F32)
    h = L.norm(p["ln2"], x, eps)
    if "moe" in p:
        y, aux = M.moe_fwd(p["moe"], cfg, h, dispatch=moe_dispatch,
                           drop_free=moe_drop_free, capacity=moe_capacity)
    elif "b_up" in p.get("mlp", {}):
        y = L.gelu_mlp(p["mlp"], h)
    else:
        y = L.swiglu(p["mlp"], h)
    return x + y, aux, kv


def _attn_block_decode(p, cfg, x, cache, pos, *, window=0, x_extra=None,
                       rope=True, rope_pos=None, block_tables=None):
    """Decode step for an attention block.  cache: dict with k/v or MLA
    leaves — per-slot rows when ``block_tables`` is None, else the
    layer's slice of the paged pool, indexed through the tables."""
    eps = cfg.norm_eps
    if x_extra is not None:
        h_in = L.norm(p["ln1"], jnp.concatenate([x, x_extra], axis=-1), eps)
    else:
        h_in = L.norm(p["ln1"], x, eps)
    if cfg.mla is not None:
        if block_tables is not None:
            a, ckv, krope = A.mla_paged_decode(p["attn"], cfg, h_in,
                                               cache["ckv"], cache["krope"],
                                               pos, block_tables)
        else:
            a, ckv, krope = A.mla_decode(p["attn"], cfg, h_in,
                                         cache["ckv"], cache["krope"], pos)
        new_cache = {"ckv": ckv, "krope": krope}
    else:
        if block_tables is not None:
            a, k, v = A.paged_attention_decode(p["attn"], cfg, h_in,
                                               cache["k"], cache["v"], pos,
                                               block_tables, window=window,
                                               rope=rope, rope_pos=rope_pos)
        else:
            a, k, v = A.attention_decode(p["attn"], cfg, h_in,
                                         cache["k"], cache["v"], pos,
                                         window=window, rope=rope,
                                         rope_pos=rope_pos)
        new_cache = {"k": k, "v": v}
    x = x + a
    if "xattn" in p:                           # whisper: static cross cache
        q = L.norm(p["ln_x"], x, eps)
        ca = _cross_decode(p["xattn"], cfg, q, cache["xk"], cache["xv"])
        x = x + ca
        new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
    h = L.norm(p["ln2"], x, eps)
    if "moe" in p:
        # decode is a serving path: never drop tokens (determinism)
        y, _ = M.moe_fwd(p["moe"], cfg, h, dispatch="einsum",
                         drop_free=True)
    elif "b_up" in p.get("mlp", {}):
        y = L.gelu_mlp(p["mlp"], h)
    else:
        y = L.swiglu(p["mlp"], h)
    return x + y, new_cache


def _cross_decode(p, cfg, q_in, xk, xv):
    """Cross-attention decode: static precomputed K/V (B,F,H,D)."""
    B = q_in.shape[0]
    hd = cfg.resolved_head_dim
    q = (q_in @ p["w_q"])
    if "b_q" in p:
        q = q + p["b_q"]
    q = q.reshape(B, 1, cfg.n_heads, hd)
    o = A.chunked_attention(q, xk, xv, causal=False)
    return o.reshape(B, 1, -1) @ p["w_o"]


# ==========================================================================
# stack descriptions
# ==========================================================================

def _stack_layout(cfg: ModelConfig):
    """Describe the scanned stacks for this config."""
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return [("attn", cfg.n_layers, {})]
    if fam == "moe":
        m = cfg.moe
        out = []
        if m.n_dense_layers:
            out.append(("attn_dense_ff", m.n_dense_layers, {}))
        out.append(("attn_moe", cfg.n_layers - m.n_dense_layers, {}))
        return out
    if fam == "hybrid":
        k = cfg.shared_attn_every
        units, tail = divmod(cfg.n_layers, k)
        return [("zamba_units", units, {"per_unit": k}),
                ("mamba_tail", tail, {})]
    if fam == "ssm":
        k = cfg.xlstm.slstm_every
        assert cfg.n_layers % k == 0
        return [("xlstm_units", cfg.n_layers // k, {"per_unit": k - 1})]
    if fam == "audio":
        return [("enc", cfg.n_encoder_layers, {}), ("dec", cfg.n_layers, {})]
    raise ValueError(fam)


def _stacked_init(key, n: int, init_one):
    return jax.vmap(init_one)(jax.random.split(key, n))


# ==========================================================================
# init
# ==========================================================================

def init_params(key, cfg: ModelConfig, max_seq: int = 4096) -> dict:
    dt = L.dtype_of(cfg.param_dtype)
    keys = jax.random.split(key, 16)
    p: dict = {
        "embed": L.init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": L.init_norm(cfg.d_model, dt, cfg.is_encoder_decoder),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(keys[1], (cfg.d_model, cfg.vocab_size), dt)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["blocks"] = _stacked_init(
            keys[2], cfg.n_layers, lambda k: _init_attn_block(k, cfg, False))
    elif fam == "moe":
        m = cfg.moe
        if m.n_dense_layers:
            p["blocks_dense"] = _stacked_init(
                keys[2], m.n_dense_layers,
                lambda k: _init_attn_block(k, cfg, False, dense_ff=m.dense_d_ff))
        p["blocks_moe"] = _stacked_init(
            keys[3], cfg.n_layers - m.n_dense_layers,
            lambda k: _init_attn_block(k, cfg, True))
    elif fam == "hybrid":
        k_every = cfg.shared_attn_every
        units, tail = divmod(cfg.n_layers, k_every)
        p["mamba_units"] = jax.vmap(
            lambda ku: _stacked_init(ku, k_every,
                                     lambda kk: S.init_mamba2(kk, cfg))
        )(jax.random.split(keys[2], units))
        if tail:
            p["mamba_tail"] = _stacked_init(
                keys[3], tail, lambda kk: S.init_mamba2(kk, cfg))
        # single weight-shared attention block over concat(h, emb) -> 2d
        p["shared_attn"] = _init_attn_block(keys[4], cfg, False,
                                            d_in=2 * cfg.d_model)
        # per-application output adapters (Zamba2-style per-depth LoRA,
        # realized as full d->d linears here)
        p["shared_adapters"] = L.dense_init(
            keys[5], (units, cfg.d_model, cfg.d_model), dt, scale=0.1)
    elif fam == "ssm":
        k_every = cfg.xlstm.slstm_every
        units = cfg.n_layers // k_every
        p["mlstm_units"] = jax.vmap(
            lambda ku: _stacked_init(ku, k_every - 1,
                                     lambda kk: X.init_mlstm_block(kk, cfg))
        )(jax.random.split(keys[2], units))
        p["slstm_units"] = _stacked_init(
            keys[3], units, lambda kk: X.init_slstm_block(kk, cfg))
    elif fam == "audio":
        p["enc_blocks"] = _stacked_init(
            keys[2], cfg.n_encoder_layers,
            lambda k: _init_attn_block(k, cfg, False, gelu=True))
        p["dec_blocks"] = _stacked_init(
            keys[3], cfg.n_layers,
            lambda k: _init_attn_block(k, cfg, False, gelu=True, cross=True))
        p["enc_ln"] = L.init_layernorm(cfg.d_model, dt)
        p["dec_pos"] = L.embed_init(keys[4], (max_seq, cfg.d_model), dt)
    if cfg.use_mtp:
        # DeepSeek-V3 MTP module [arXiv:2412.19437 §2.2]: combine the
        # trunk's hidden state with the NEXT token's embedding, run one
        # extra transformer block, share the unembedding.
        mk = jax.random.split(keys[15], 2)
        p["mtp"] = {
            "norm_h": L.init_rmsnorm(cfg.d_model, dt),
            "norm_e": L.init_rmsnorm(cfg.d_model, dt),
            "proj": L.dense_init(mk[0], (2 * cfg.d_model, cfg.d_model), dt),
            "block": _init_attn_block(
                mk[1], cfg, False,
                dense_ff=(cfg.moe.dense_d_ff if cfg.moe
                          and cfg.moe.dense_d_ff else cfg.d_ff)),
            "final_norm": L.init_rmsnorm(cfg.d_model, dt),
        }
    return p


# ==========================================================================
# position helpers
# ==========================================================================

def mrope_positions(cfg: ModelConfig, B: int, n_patches: int, s_text: int,
                    offset: int = 0):
    """Qwen2-VL M-RoPE position triples (3, B, S) for [patches | text]."""
    grid = int(n_patches ** 0.5) or 1
    pi = jnp.arange(n_patches)
    pt = jnp.zeros((n_patches,), jnp.int32)
    ph = (pi // grid).astype(jnp.int32)
    pw = (pi % grid).astype(jnp.int32)
    t0 = grid  # text starts after the max spatial position
    ti = t0 + jnp.arange(s_text, dtype=jnp.int32)
    pos = jnp.stack([
        jnp.concatenate([pt, ti]),
        jnp.concatenate([ph, ti]),
        jnp.concatenate([pw, ti]),
    ])                                            # (3, S)
    return jnp.broadcast_to(pos[:, None, :] + offset, (3, B, pos.shape[-1]))


# ==========================================================================
# forward (train / prefill)
# ==========================================================================

def forward(params: dict, cfg: ModelConfig, batch: dict, *,
            mode: str = "flash", moe_dispatch: str = "einsum",
            moe_drop_free: bool = False, moe_capacity=None, window: int = 0,
            return_cache: bool = False, return_hidden: bool = False,
            remat: bool = True):
    """Returns (logits, aux_loss[, cache][, hidden]).

    moe_drop_free: route MoE tokens with drop-free expert capacity —
    REQUIRED on serving forwards (prefill, reference logits compared
    against decode) so results are batch-composition independent; leave
    False for training (capacity-bounded GShard throughput).
    moe_capacity: optional static per-batch expert-capacity bound for
    drop-free serving prefill — when set, aux_loss reports the number of
    OVERFLOWED routings instead of the balance loss (0.0 == the result
    is token-exact with the unbounded drop-free path; the engines retry
    with a larger bound otherwise).  See ``moe.moe_fwd``."""
    window = window or cfg.sliding_window
    fam = cfg.family
    if fam == "audio":
        return _whisper_forward(params, cfg, batch, mode=mode,
                                return_cache=return_cache, remat=remat)

    tokens = batch["tokens"]
    B, S_text = tokens.shape
    x = L.embed(params["embed"], tokens)
    if fam == "vlm":
        pe = batch["patch_embeds"].astype(x.dtype)         # (B, P, d)
        x = jnp.concatenate([pe, x], axis=1)
        positions = mrope_positions(cfg, B, pe.shape[1], S_text)
    else:
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])
    x = shard(x, "batch", None, None)
    aux_total = jnp.zeros((), F32)
    cache = {}

    def run_stack(x, aux_total, stack_params, block_fn):
        def body(carry, lp):
            xc, aux = carry
            xn, a, kv = block_fn(xc, lp)
            return (xn, aux + a), kv
        body = jax.checkpoint(body) if remat else body
        (x, aux_total), kvs = jax.lax.scan(body, (x, aux_total), stack_params)
        return x, aux_total, kvs

    if fam in ("dense", "vlm"):
        fn = lambda xc, lp: _attn_block_fwd(
            lp, cfg, xc, positions, window=window, mode=mode,
            return_kv=return_cache)
        x, aux_total, kvs = run_stack(x, aux_total, params["blocks"], fn)
        if return_cache:
            cache["blocks"] = {"k": kvs[0], "v": kvs[1]}
    elif fam == "moe":
        if "blocks_dense" in params:
            fn = lambda xc, lp: _attn_block_fwd(
                lp, cfg, xc, positions, window=window, mode=mode,
                return_kv=return_cache)
            x, aux_total, kvs = run_stack(x, aux_total,
                                          params["blocks_dense"], fn)
            if return_cache:
                cache["blocks_dense"] = _kv_cache_entry(cfg, kvs)
        fn = lambda xc, lp: _attn_block_fwd(
            lp, cfg, xc, positions, window=window, mode=mode,
            moe_dispatch=moe_dispatch, moe_drop_free=moe_drop_free,
            moe_capacity=moe_capacity, return_kv=return_cache)
        x, aux_total, kvs = run_stack(x, aux_total, params["blocks_moe"], fn)
        if return_cache:
            cache["blocks_moe"] = _kv_cache_entry(cfg, kvs)
    elif fam == "hybrid":
        x, aux_total, cache = _zamba_forward(
            params, cfg, x, positions, aux_total, mode=mode, window=window,
            return_cache=return_cache, remat=remat)
    elif fam == "ssm":
        x, cache = _xlstm_forward(params, cfg, x, return_cache=return_cache,
                                  remat=remat)
    else:
        raise ValueError(fam)

    hidden = x
    x = L.norm(params["final_norm"], x, cfg.norm_eps)
    logits = _lm_logits(params, cfg, x)
    if return_cache and return_hidden:
        return logits, aux_total, cache, hidden
    if return_cache:
        return logits, aux_total, cache
    if return_hidden:
        return logits, aux_total, hidden
    return logits, aux_total


def mtp_logits(params: dict, cfg: ModelConfig, hidden, tokens, *,
               mode: str = "flash"):
    """MTP head: h'_t = proj([norm(h_t); norm(emb(tok_{t+1}))]) for
    t in [0, S-2), one extra block, shared unembedding -> predicts
    tok_{t+2}.  Returns logits (B, S-2, V)."""
    p = params["mtp"]
    B, S = tokens.shape
    h = L.rmsnorm(p["norm_h"], hidden[:, :S - 2], cfg.norm_eps)
    e = L.rmsnorm(p["norm_e"],
                  L.embed(params["embed"], tokens[:, 1:S - 1]), cfg.norm_eps)
    x = jnp.concatenate([h, e], axis=-1) @ p["proj"]
    positions = jnp.broadcast_to(jnp.arange(S - 2)[None], (B, S - 2))
    x, _, _ = _attn_block_fwd(p["block"], cfg, x, positions, mode=mode)
    x = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    return _lm_logits(params, cfg, x)


def _lm_logits(params, cfg, x):
    if cfg.tie_embeddings:
        return L.unembed(params["embed"], x, transpose=True)
    return L.unembed(params["lm_head"], x, transpose=False)


def _kv_cache_entry(cfg, kvs):
    if cfg.mla is not None:
        return {"ckv": kvs[0], "krope": kvs[1]}
    return {"k": kvs[0], "v": kvs[1]}


# --------------------------------------------------------------------------
# zamba2 / xlstm / whisper forward bodies
# --------------------------------------------------------------------------

def _zamba_forward(params, cfg, x, positions, aux_total, *, mode, window,
                   return_cache, remat):
    emb0 = x                                       # original embedding stream
    cache: dict = {}

    def mamba_one(carry, lp):
        xc = carry
        if return_cache:
            y, st = S.mamba2_fwd(lp, cfg, xc, return_state=True)
            return xc + y, st
        return xc + S.mamba2_fwd(lp, cfg, xc), None

    mamba_one_ck = jax.checkpoint(mamba_one) if remat else mamba_one

    def unit(carry, inp):
        xc = carry
        unit_params, adapter = inp
        xc, sts = jax.lax.scan(mamba_one_ck, xc, unit_params)
        y, _, kv = _attn_block_fwd(params["shared_attn"], cfg, xc, positions,
                                   window=window, mode=mode,
                                   return_kv=return_cache, x_extra=emb0)
        xc = xc + (y - xc) @ adapter               # per-application adapter
        return xc, (sts, kv)

    unit_ck = jax.checkpoint(unit) if remat else unit
    x, (mamba_sts, attn_kvs) = jax.lax.scan(
        unit_ck, x, (params["mamba_units"], params["shared_adapters"]))
    if return_cache:
        cache["mamba_units"] = mamba_sts
        cache["shared_attn"] = _kv_cache_entry(cfg, attn_kvs)
    if "mamba_tail" in params:
        x, tail_sts = jax.lax.scan(mamba_one_ck, x, params["mamba_tail"])
        if return_cache:
            cache["mamba_tail"] = tail_sts
    return x, aux_total, cache


def _xlstm_forward(params, cfg, x, *, return_cache, remat):
    cache: dict = {}

    def mlstm_one(carry, lp):
        if return_cache:
            y, st = X.mlstm_block_fwd(lp, cfg, carry, return_state=True)
            return y, st
        return X.mlstm_block_fwd(lp, cfg, carry), None

    mlstm_one_ck = jax.checkpoint(mlstm_one) if remat else mlstm_one

    def unit(carry, inp):
        xc = carry
        m_params, s_params = inp
        xc, msts = jax.lax.scan(mlstm_one_ck, xc, m_params)
        if return_cache:
            xc, sst = X.slstm_block_fwd(s_params, cfg, xc, return_state=True)
        else:
            xc, sst = X.slstm_block_fwd(s_params, cfg, xc), None
        return xc, (msts, sst)

    unit_ck = jax.checkpoint(unit) if remat else unit
    x, (msts, ssts) = jax.lax.scan(
        unit_ck, x, (params["mlstm_units"], params["slstm_units"]))
    if return_cache:
        cache["mlstm_units"] = msts
        cache["slstm_units"] = ssts
    return x, cache


def _whisper_forward(params, cfg, batch, *, mode, return_cache, remat):
    frames = batch["audio_frames"]                 # (B, F, d) frontend stub
    tokens = batch["tokens"]                       # (B, S)
    B, S = tokens.shape
    dt = L.dtype_of(cfg.activation_dtype)

    # ---- encoder (non-causal, sinusoidal positions)
    enc = frames.astype(dt) + L.sinusoidal_positions(
        frames.shape[1], cfg.d_model).astype(dt)[None]
    zero_pos = jnp.zeros((B, frames.shape[1]), jnp.int32)

    def enc_body(carry, lp):
        y, _, _ = _attn_block_fwd(lp, cfg, carry, zero_pos, causal=False,
                                  rope=False, mode=mode)
        return y, None
    enc_body = jax.checkpoint(enc_body) if remat else enc_body
    enc, _ = jax.lax.scan(enc_body, enc, params["enc_blocks"])
    enc = L.layernorm(params["enc_ln"], enc, cfg.norm_eps)

    # ---- decoder (causal self-attn + cross-attn, learned positions)
    pos_tab = params["dec_pos"]
    x = L.embed(params["embed"], tokens) + pos_tab[None, :S].astype(dt)
    dpos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def dec_body(carry, lp):
        y, _, kv = _attn_block_fwd(lp, cfg, carry, dpos, causal=True,
                                   rope=False, mode=mode, enc_out=enc,
                                   return_kv=return_cache)
        return y, kv
    dec_body = jax.checkpoint(dec_body) if remat else dec_body
    x, kvs = jax.lax.scan(dec_body, x, params["dec_blocks"])

    x = L.norm(params["final_norm"], x, cfg.norm_eps)
    logits = _lm_logits(params, cfg, x)
    aux = jnp.zeros((), F32)
    if return_cache:
        (selfkv, crosskv) = kvs
        cache = {"dec": {"k": selfkv[0], "v": selfkv[1],
                         "xk": crosskv[0], "xv": crosskv[1]}}
        return logits, aux, cache
    return logits, aux


# ==========================================================================
# loss
# ==========================================================================

def loss_fn(params, cfg: ModelConfig, batch: dict, *, mode="flash",
            moe_dispatch="einsum", remat=True):
    """Next-token cross-entropy (text positions only for VLM) + the MTP
    auxiliary loss when the config carries an MTP head (deepseek-v3).
    Returns (loss, metrics)."""
    mtp_loss = jnp.zeros((), F32)
    if cfg.use_mtp:
        logits, aux, hidden = forward(params, cfg, batch, mode=mode,
                                      moe_dispatch=moe_dispatch,
                                      return_hidden=True, remat=remat)
        toks = batch["tokens"]
        ml = mtp_logits(params, cfg, hidden, toks, mode=mode)
        mlp_ = jax.nn.log_softmax(ml.astype(F32), axis=-1)
        mtp_nll = -jnp.take_along_axis(
            mlp_, toks[:, 2:][..., None], axis=-1)[..., 0]
        mtp_loss = cfg.mtp_weight * jnp.mean(mtp_nll)
    else:
        logits, aux = forward(params, cfg, batch, mode=mode,
                              moe_dispatch=moe_dispatch, remat=remat)
    tokens = batch["tokens"]
    if cfg.family == "vlm":
        logits = logits[:, -tokens.shape[1]:]      # text tail only
    targets = tokens[:, 1:]
    logits = logits[:, :-1]
    logp = jax.nn.log_softmax(logits.astype(F32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is not None:
        mask = mask[:, 1:]
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        loss = jnp.mean(nll)
    total = loss + aux + mtp_loss
    return total, {"loss": loss, "aux_loss": aux, "mtp_loss": mtp_loss,
                   "perplexity": jnp.exp(jnp.minimum(loss, 20.0))}


# ==========================================================================
# KV-cache init + decode step
# ==========================================================================

def _attn_cache_struct(cfg, n_layers, B, max_seq, dtype):
    hd = cfg.resolved_head_dim
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "ckv": jnp.zeros((n_layers, B, max_seq, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((n_layers, B, max_seq, m.qk_rope_head_dim), dtype),
        }
    S_c = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    return {
        "k": jnp.zeros((n_layers, B, S_c, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((n_layers, B, S_c, cfg.n_kv_heads, hd), dtype),
    }


def init_cache(cfg: ModelConfig, B: int, max_seq: int) -> dict:
    """Zero-initialized cache pytree for decoding up to max_seq tokens."""
    dt = L.dtype_of(cfg.activation_dtype)
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return {"blocks": _attn_cache_struct(cfg, cfg.n_layers, B, max_seq, dt)}
    if fam == "moe":
        m = cfg.moe
        c = {}
        if m.n_dense_layers:
            c["blocks_dense"] = _attn_cache_struct(
                cfg, m.n_dense_layers, B, max_seq, dt)
        c["blocks_moe"] = _attn_cache_struct(
            cfg, cfg.n_layers - m.n_dense_layers, B, max_seq, dt)
        return c
    if fam == "hybrid":
        s = cfg.ssm
        d_inner, nh = S._dims(cfg)
        conv_ch = d_inner + 2 * s.n_groups * s.d_state
        k_every = cfg.shared_attn_every
        units, tail = divmod(cfg.n_layers, k_every)
        c = {
            "mamba_units": {
                "ssm": jnp.zeros((units, k_every, B, nh, s.head_dim, s.d_state), F32),
                "conv": jnp.zeros((units, k_every, B, s.d_conv - 1, conv_ch), dt),
            },
            "shared_attn": _attn_cache_struct(cfg, units, B, max_seq, dt),
        }
        if tail:
            c["mamba_tail"] = {
                "ssm": jnp.zeros((tail, B, nh, s.head_dim, s.d_state), F32),
                "conv": jnp.zeros((tail, B, s.d_conv - 1, conv_ch), dt),
            }
        return c
    if fam == "ssm":
        xl = cfg.xlstm
        d_inner, nh, dh = X.mlstm_dims(cfg)
        units = cfg.n_layers // xl.slstm_every
        per = xl.slstm_every - 1
        d = cfg.d_model
        nh_s, dh_s = cfg.n_heads, d // cfg.n_heads
        return {
            "mlstm_units": {
                "C": jnp.zeros((units, per, B, nh, dh, dh), F32),
                "n": jnp.zeros((units, per, B, nh, dh), F32),
                "m": jnp.full((units, per, B, nh), -1e30, F32),
                "conv": jnp.zeros((units, per, B, xl.d_conv - 1, d_inner), dt),
            },
            "slstm_units": {
                "h": jnp.zeros((units, B, d), F32),
                "c": jnp.zeros((units, B, nh_s, dh_s), F32),
                "n": jnp.full((units, B, nh_s, dh_s), 1e-6, F32),
                "m": jnp.zeros((units, B, nh_s, dh_s), F32),
                "conv_win": jnp.zeros((units, B, xl.d_conv - 1, d), dt),
            },
        }
    if fam == "audio":
        hd = cfg.resolved_head_dim
        c = _attn_cache_struct(cfg, cfg.n_layers, B, max_seq, dt)
        c["xk"] = jnp.zeros((cfg.n_layers, B, cfg.n_audio_frames,
                             cfg.n_kv_heads, hd), dt)
        c["xv"] = jnp.zeros_like(c["xk"])
        return {"dec": c}
    raise ValueError(fam)


def graft_slot_cache(cache: dict, prefix_cache: dict, slot) -> dict:
    """Write a single-sequence prefix cache (batch axis of size 1) into
    slot ``slot`` of a multi-slot cache, leaf by leaf.  The batch axis of
    each leaf is the first axis where the two shapes differ; any trailing
    mismatch (the sequence axis, shorter in the prefix) starts at 0, so
    stale cache beyond the prefix stays in place and must be masked by
    the caller's per-slot lengths until overwritten."""
    def graft(big, small):
        start = [0] * big.ndim
        for i, (a, b) in enumerate(zip(big.shape, small.shape)):
            if a != b:
                start[i] = slot
                break
        return jax.lax.dynamic_update_slice(
            big, small.astype(big.dtype), tuple(start))
    return jax.tree.map(graft, cache, prefix_cache)


# --------------------------------------------------------------------------
# paged KV cache (dense / moe attention families)
# --------------------------------------------------------------------------

def _paged_attn_cache_struct(cfg, n_layers, n_pages, page_size, dtype):
    hd = cfg.resolved_head_dim
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "ckv": jnp.zeros((n_layers, n_pages, page_size, m.kv_lora_rank),
                             dtype),
            "krope": jnp.zeros((n_layers, n_pages, page_size,
                                m.qk_rope_head_dim), dtype),
        }
    return {
        "k": jnp.zeros((n_layers, n_pages, page_size, cfg.n_kv_heads, hd),
                       dtype),
        "v": jnp.zeros((n_layers, n_pages, page_size, cfg.n_kv_heads, hd),
                       dtype),
    }


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int) -> dict:
    """Zero-initialized paged KV pool: pages replace the per-slot
    ``(B, max_seq)`` reservation of ``init_cache``; which sequence owns
    which page lives in the engine's block tables.  Attention-cached
    families only — recurrent state (hybrid/ssm) is fixed-size per slot
    and keeps the contiguous layout.  Sliding-window archs store full
    absolute positions per page (the window is enforced by masking, not
    by a ring buffer)."""
    dt = L.dtype_of(cfg.activation_dtype)
    fam = cfg.family
    if fam == "dense":
        return {"blocks": _paged_attn_cache_struct(
            cfg, cfg.n_layers, n_pages, page_size, dt)}
    if fam == "moe":
        m = cfg.moe
        c = {}
        if m.n_dense_layers:
            c["blocks_dense"] = _paged_attn_cache_struct(
                cfg, m.n_dense_layers, n_pages, page_size, dt)
        c["blocks_moe"] = _paged_attn_cache_struct(
            cfg, cfg.n_layers - m.n_dense_layers, n_pages, page_size, dt)
        return c
    raise NotImplementedError(
        f"paged KV cache unsupported for family {cfg.family!r} "
        "(recurrent families keep their fixed-size state path)")


def graft_paged_cache(cache: dict, prefix_cache: dict, page_ids,
                      since: int = 0) -> dict:
    """Scatter a single-sequence prefix cache (leaves (L, 1, S_b, ...))
    into pages ``page_ids`` ((n0,) int32) of the paged pool.  The prefix
    is padded/clamped to ``n0 * page_size`` positions, so every written
    page is fully overwritten — positions beyond the true prompt length
    hold prefill padding garbage and stay masked by the per-slot
    ``kv_len`` exactly as in the contiguous layout.

    ``since`` (static) skips the first ``since`` entries of ``page_ids``:
    the delta half of the KV-delta spill format — a re-resumed sequence
    whose leading pages are already device-resident (or already grafted
    from a base snapshot) grafts only the pages dirtied since the last
    spill, and base + delta reassemble token-exactly.  A shared-prefix
    resume passes only its private page ids here: the shared prefix
    never left the pool, so nothing is grafted over it."""
    if since:
        page_ids = page_ids[since:]
    def graft(pool, small):
        ps = pool.shape[2]
        n0 = page_ids.shape[0]
        need = n0 * ps
        sm = small[:, 0]                          # (L, S_b, ...)
        Sb = sm.shape[1]
        if Sb < need:
            pad = [(0, 0)] * sm.ndim
            pad[1] = (0, need - Sb)
            sm = jnp.pad(sm, pad)
        else:
            sm = sm[:, :need]
        sm = sm.reshape(sm.shape[0], n0, ps, *sm.shape[2:])
        return pool.at[:, page_ids].set(sm.astype(pool.dtype))
    return jax.tree.map(graft, cache, prefix_cache)


def extract_paged_cache(cache: dict, page_ids, since: int = 0) -> dict:
    """Gather pages ``page_ids`` ((n,) int32) of the paged pool back into
    a single-sequence prefix cache (leaves (L, 1, n * page_size, ...)) —
    the exact inverse of ``graft_paged_cache``.  Preemption snapshots a
    live sequence's KV with this, releases its pages, and later resumes
    by grafting the snapshot into freshly allocated pages; because the
    snapshot length is a whole number of pages, the graft pads nothing
    and the round trip is bit-exact.

    ``since`` (static) gathers only ``page_ids[since:]`` — the pages
    dirtied since a previous spill epoch.  Re-preempting a long sequence
    then ships only its new pages; the host store keeps the clean prefix
    from the earlier spill (``serving.paging.DeltaSpillStore``).  The
    same slicing marks a SHARED-prefix boundary: a sequence holding
    prefix-index pages spills with ``since >= shared_pages`` so pages
    still referenced elsewhere are never re-shipped — they stay pinned
    in the pool and the resume grafts only the private tail after
    them."""
    if since:
        page_ids = page_ids[since:]
    def gather(pool):
        sm = pool[:, page_ids]                    # (L, n, ps, ...)
        L, n, ps = sm.shape[:3]
        return sm.reshape(L, 1, n * ps, *sm.shape[3:])
    return jax.tree.map(gather, cache)


def copy_paged_pages(cache: dict, src_ids, dst_ids) -> dict:
    """Duplicate pages ``src_ids`` of the paged pool into ``dst_ids``
    (both (n,) int32) — the device-side half of copy-on-write forking.
    A sequence about to write into a page it shares with the prefix
    index (refcount > 1) first copies the page into a private one drawn
    from its own reservation, then redirects its block table; whole
    pages move, so the fork is bit-exact with the shared original and
    no other holder ever observes the write."""
    def cp(pool):
        return pool.at[:, dst_ids].set(pool[:, src_ids])
    return jax.tree.map(cp, cache)


def extract_slot_cache(cache: dict, template: dict, slot) -> dict:
    """Slice slot ``slot`` of a multi-slot cache into a single-sequence
    cache shaped like ``template`` (a batch-1 pytree from ``init_cache``)
    — the inverse of ``graft_slot_cache``.  The batch axis of each leaf
    is the first axis where the two shapes differ."""
    def gather(big, tmpl):
        start = [0] * big.ndim
        for i, (a, b) in enumerate(zip(big.shape, tmpl.shape)):
            if a != b:
                start[i] = slot
                break
        return jax.lax.dynamic_slice(big, tuple(start), tmpl.shape)
    return jax.tree.map(gather, cache, template)


def decode_step(params: dict, cfg: ModelConfig, cache: dict,
                tokens: jax.Array, pos,
                block_tables=None) -> Tuple[jax.Array, dict]:
    """One decode step.  tokens: (B, 1) int32.  pos is either a scalar
    int32 (all sequences at the same absolute position — the fixed-slot
    engine) or a (B,) vector of per-sequence positions (continuous
    batching: each cache slot is at its own depth; cache writes and
    attention masks are resolved per slot).  block_tables: optional
    (B, max_pages) int32 per-slot page ids (scratch page 0 for inactive
    slots / unused entries) — when given, cache leaves are
    ``init_paged_cache`` pools and reads/writes go through the tables
    (dense/moe only).  Returns (logits (B,1,V), new_cache)."""
    fam = cfg.family
    window = cfg.sliding_window
    per_slot = jnp.asarray(pos).ndim == 1
    if per_slot and fam == "audio":
        raise NotImplementedError(
            "per-slot decode positions unsupported for encoder-decoder "
            "audio (learned positions are looked up with a scalar index)")
    if block_tables is not None and fam not in ("dense", "moe"):
        raise NotImplementedError(
            f"paged decode unsupported for family {cfg.family!r} "
            "(recurrent families keep their fixed-size state path)")
    x = L.embed(params["embed"], tokens)
    rope = fam != "audio"
    rope_pos = None
    if fam == "audio":
        x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, 0)[None]
    if fam == "vlm":
        # M-RoPE: text rotary positions restart after the patch grid —
        # slot index pos counts [patches | text], rotary counts grid + i
        grid = int(cfg.n_patches ** 0.5) or 1
        rope_pos = pos - cfg.n_patches + grid
    new_cache: dict = {}

    def scan_attn(x, stack_params, stack_cache):
        def body(xc, inp):
            lp, lc = inp
            xn, nc = _attn_block_decode(lp, cfg, xc, lc, pos, window=window,
                                        rope=rope, rope_pos=rope_pos,
                                        block_tables=block_tables)
            return xn, nc
        return jax.lax.scan(body, x, (stack_params, stack_cache))

    if fam in ("dense", "vlm"):
        x, new_cache["blocks"] = scan_attn(x, params["blocks"], cache["blocks"])
    elif fam == "moe":
        if "blocks_dense" in params:
            x, new_cache["blocks_dense"] = scan_attn(
                x, params["blocks_dense"], cache["blocks_dense"])
        x, new_cache["blocks_moe"] = scan_attn(
            x, params["blocks_moe"], cache["blocks_moe"])
    elif fam == "hybrid":
        x, new_cache = _zamba_decode(params, cfg, x, cache, pos, window)
    elif fam == "ssm":
        x, new_cache = _xlstm_decode(params, cfg, x, cache)
    elif fam == "audio":
        x, new_cache["dec"] = scan_attn(x, params["dec_blocks"], cache["dec"])
    x = L.norm(params["final_norm"], x, cfg.norm_eps)
    return _lm_logits(params, cfg, x), new_cache


def _zamba_decode(params, cfg, x, cache, pos, window):
    emb0 = x

    def mamba_one(xc, inp):
        lp, lc = inp
        y, nc = S.mamba2_decode(lp, cfg, xc, lc)
        return xc + y, nc

    def unit(xc, inp):
        (u_params, adapter), (u_mcache, u_acache) = inp
        xc, mnc = jax.lax.scan(mamba_one, xc, (u_params, u_mcache))
        y, anc = _attn_block_decode(params["shared_attn"], cfg, xc, u_acache,
                                    pos, window=window, x_extra=emb0)
        xc = xc + (y - xc) @ adapter
        return xc, (mnc, anc)

    x, (mnc, anc) = jax.lax.scan(
        unit, x,
        ((params["mamba_units"], params["shared_adapters"]),
         (cache["mamba_units"], cache["shared_attn"])))
    new_cache = {"mamba_units": mnc, "shared_attn": anc}
    if "mamba_tail" in params:
        x, tnc = jax.lax.scan(mamba_one, x,
                              (params["mamba_tail"], cache["mamba_tail"]))
        new_cache["mamba_tail"] = tnc
    return x, new_cache


def _xlstm_decode(params, cfg, x, cache):
    def mlstm_one(xc, inp):
        lp, lc = inp
        return X.mlstm_block_decode(lp, cfg, xc, lc)

    def unit(xc, inp):
        (m_params, s_params), (m_cache, s_cache) = inp
        xc, mnc = jax.lax.scan(mlstm_one, xc, (m_params, m_cache))
        xc, snc = X.slstm_block_decode(s_params, cfg, xc, s_cache)
        return xc, (mnc, snc)

    x, (mnc, snc) = jax.lax.scan(
        unit, x,
        ((params["mlstm_units"], params["slstm_units"]),
         (cache["mlstm_units"], cache["slstm_units"])))
    return x, {"mlstm_units": mnc, "slstm_units": snc}


# ==========================================================================
# chunked prefill into the paged cache (unified token-budget step)
# ==========================================================================

def _attn_block_prefill_chunk(p, cfg, x, cache, pos_offset, n_valid,
                              block_tables, *, window=0, moe_capacity=None):
    """Prefill-chunk step for an attention block: the chunk's KV goes
    straight into the block's slice of the paged pool (no contiguous
    prefix cache exists at any point).  Returns (x, new_cache, aux)
    where aux is the MoE overflow count under ``moe_capacity`` (0 for
    dense blocks / unbounded capacity)."""
    eps = cfg.norm_eps
    h_in = L.norm(p["ln1"], x, eps)
    if cfg.mla is not None:
        a, ckv, krope = A.mla_paged_prefill(p["attn"], cfg, h_in,
                                            cache["ckv"], cache["krope"],
                                            pos_offset, n_valid, block_tables)
        new_cache = {"ckv": ckv, "krope": krope}
    else:
        a, k, v = A.paged_prefill_attention(p["attn"], cfg, h_in,
                                            cache["k"], cache["v"],
                                            pos_offset, n_valid, block_tables,
                                            window=window)
        new_cache = {"k": k, "v": v}
    x = x + a
    aux = jnp.zeros((), F32)
    h = L.norm(p["ln2"], x, eps)
    if "moe" in p:
        # serving path: drop-free routing; a bounded capacity reports
        # overflow through aux so the engine can retry with a larger one
        y, aux = M.moe_fwd(p["moe"], cfg, h, dispatch="einsum",
                           drop_free=True, capacity=moe_capacity)
    elif "b_up" in p.get("mlp", {}):
        y = L.gelu_mlp(p["mlp"], h)
    else:
        y = L.swiglu(p["mlp"], h)
    return x + y, new_cache, aux


def prefill_chunk(params: dict, cfg: ModelConfig, cache: dict, tokens,
                  n_valid, pos_offset, block_tables, *,
                  moe_capacity=None) -> Tuple[jax.Array, jax.Array, dict]:
    """One prompt chunk of a single sequence, written DIRECTLY into the
    paged KV pool — the admission contract of the unified token-budget
    step (dense / moe incl. MLA; recurrent families keep monolithic
    prefill on their contiguous state).

    tokens: (1, C) int32 — chunk positions ``[pos_offset, pos_offset+C)``
    of the prompt, of which the first ``n_valid`` (dynamic) are real and
    the rest are jit-bucketing pads whose KV lands on the scratch page.
    cache: an ``init_paged_cache`` pool.  block_tables: (1, max_pages)
    int32 covering at least positions [0, pos_offset + n_valid).

    Returns (logits (1, C, V), moe_overflow, new_cache).  The logits
    are PER-POSITION next-token distributions — ``logits[0, i]``
    predicts the token after ``tokens[0, i]`` given everything up to
    ``pos_offset + i`` — and that contract is load-bearing twice over:
    prompt admission takes ``logits[0, n_valid-1]`` of the final chunk
    as the first emitted token's distribution, and speculative
    draft-verify (``serving.engine.ContinuousEngine._verify_slot``)
    runs a chunk of ``[last_token, d_1..d_k]`` mid-decode and compares
    every position's argmax against the next draft to accept the
    longest agreeing prefix in one pass.  ``moe_overflow`` is nonzero
    when ``moe_capacity`` dropped routings (the engine doubles and
    retries — the same dynamic-capacity discipline as monolithic
    serving prefill, applied per chunk)."""
    fam = cfg.family
    if fam not in ("dense", "moe"):
        raise NotImplementedError(
            f"chunked paged prefill unsupported for family {cfg.family!r} "
            "(recurrent families keep their monolithic prefill path)")
    window = cfg.sliding_window
    x = L.embed(params["embed"], tokens)
    aux_total = jnp.zeros((), F32)
    new_cache: dict = {}

    def scan_chunk(x, aux_total, stack_params, stack_cache):
        def body(carry, inp):
            xc, aux = carry
            lp, lc = inp
            xn, nc, a = _attn_block_prefill_chunk(
                lp, cfg, xc, lc, pos_offset, n_valid, block_tables,
                window=window, moe_capacity=moe_capacity)
            return (xn, aux + a), nc
        (x, aux_total), nc = jax.lax.scan(body, (x, aux_total),
                                          (stack_params, stack_cache))
        return x, aux_total, nc

    if fam == "dense":
        x, aux_total, new_cache["blocks"] = scan_chunk(
            x, aux_total, params["blocks"], cache["blocks"])
    else:
        if "blocks_dense" in params:
            x, aux_total, new_cache["blocks_dense"] = scan_chunk(
                x, aux_total, params["blocks_dense"], cache["blocks_dense"])
        x, aux_total, new_cache["blocks_moe"] = scan_chunk(
            x, aux_total, params["blocks_moe"], cache["blocks_moe"])
    x = L.norm(params["final_norm"], x, cfg.norm_eps)
    return _lm_logits(params, cfg, x), aux_total, new_cache


# ==========================================================================
# prefill convenience
# ==========================================================================

def prefill(params, cfg: ModelConfig, batch: dict, *, mode="flash",
            moe_dispatch: str = "einsum", moe_capacity=None):
    """Run the full prompt, returning (last-position logits, cache)."""
    logits, aux, cache = forward(params, cfg, batch, mode=mode,
                                 moe_dispatch=moe_dispatch,
                                 moe_drop_free=True,
                                 moe_capacity=moe_capacity,
                                 return_cache=True, remat=False)
    return logits[:, -1:], cache
