"""Attention: GQA/MQA, sliding-window, MLA (DeepSeek-V3), cross-attention.

Three execution paths:
  * ``chunked_attention`` — pure-jnp flash-style attention: a
    ``lax.scan`` over query blocks with fp32 softmax, bounding peak
    activation memory to (block_q x seq) instead of (seq x seq).  This is
    the path the multi-pod dry-run lowers (TPU kernels cannot compile on
    the CPU host platform); on real TPU ``repro.kernels.ops`` swaps in
    the Pallas flash kernel.
  * ``triangular`` — causal block-skipping variant (perf pass): query
    blocks are unrolled and each attends only keys ``<= block_end``,
    halving attention FLOPs vs the chunked path.
  * decode — one query token against a (possibly ring-buffered) KV cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models.flash import flash_attention
from repro.models.pspec import shard

NEG_INF = -1e30


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, d_in: Optional[int] = None) -> dict:
    """Standard GQA attention params. d_in overrides the input width
    (zamba2's shared block consumes concat(hidden, embedding))."""
    dt = L.dtype_of(cfg.param_dtype)
    d = d_in or cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "w_q": L.dense_init(ks[0], (d, cfg.n_heads * hd), dt),
        "w_k": L.dense_init(ks[1], (d, cfg.n_kv_heads * hd), dt),
        "w_v": L.dense_init(ks[2], (d, cfg.n_kv_heads * hd), dt),
        "w_o": L.dense_init(ks[3], (cfg.n_heads * hd, cfg.d_model), dt),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((cfg.n_heads * hd,), dt)
        p["b_k"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
        p["b_v"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = L.init_rmsnorm(hd, dt)
        p["k_norm"] = L.init_rmsnorm(hd, dt)
    return p


def init_mla(key, cfg: ModelConfig) -> dict:
    """DeepSeek-V3 Multi-head Latent Attention [arXiv:2412.19437]."""
    m = cfg.mla
    dt = L.dtype_of(cfg.param_dtype)
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": L.dense_init(ks[0], (d, m.q_lora_rank), dt),
        "q_norm": L.init_rmsnorm(m.q_lora_rank, dt),
        "w_uq": L.dense_init(ks[1], (m.q_lora_rank, H * qk_head), dt),
        # down-projection to the compressed latent + the shared rope key
        "w_dkv": L.dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dt),
        "kv_norm": L.init_rmsnorm(m.kv_lora_rank, dt),
        # up-projections from the latent: k_nope and v per head
        "w_uk": L.dense_init(ks[3], (m.kv_lora_rank, H * m.qk_nope_head_dim), dt),
        "w_uv": L.dense_init(ks[4], (m.kv_lora_rank, H * m.v_head_dim), dt),
        "w_o": L.dense_init(ks[5], (H * m.v_head_dim, d), dt),
    }


# --------------------------------------------------------------------------
# qkv projection helpers
# --------------------------------------------------------------------------

def _project_qkv(p: dict, cfg: ModelConfig, x, xkv=None):
    hd = cfg.resolved_head_dim
    B = x.shape[0]
    xkv = x if xkv is None else xkv
    q = x @ p["w_q"]
    k = xkv @ p["w_k"]
    v = xkv @ p["w_v"]
    if "b_q" in p:
        q, k, v = q + p["b_q"], k + p["b_k"], v + p["b_v"]
    q = q.reshape(B, -1, cfg.n_heads, hd)
    k = k.reshape(B, -1, cfg.n_kv_heads, hd)
    v = v.reshape(B, -1, cfg.n_kv_heads, hd)
    if "q_norm" in p:
        q = L.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = L.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = shard(q, "batch", None, "model", None)
    k = shard(k, "batch", None, "model", None)
    v = shard(v, "batch", None, "model", None)
    return q, k, v


# --------------------------------------------------------------------------
# chunked (flash-style) attention over full sequences
# --------------------------------------------------------------------------

def _grouped_scores(q, k):
    """q: (B, Sq, Hkv, g, D), k: (B, Skv, Hkv, D) -> (B, Hkv, g, Sq, Skv)."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                      k.astype(jnp.float32))


def chunked_attention(q, k, v, *, causal: bool, q_offset=0,
                      window: int = 0, kv_len: Optional[jax.Array] = None,
                      kv_start: Optional[jax.Array] = None,
                      block_q: int = 1024) -> jax.Array:
    """Memory-bounded attention.  q: (B,Sq,H,D); k,v: (B,Skv,Hkv,D).

    q_offset: absolute position of q[0] (prefill continuation / decode).
    window: sliding-window size (0 = full).
    kv_len: optional dynamic number of valid kv positions (decode);
        scalar, or (B,) for per-sequence lengths (continuous batching
        steps slots whose sequences are at different positions).
    kv_start: optional first valid kv position, scalar or (B,) — the
        paged decode path enforces a sliding window by lower bound
        (kv positions there are absolute, not ring-buffered).
    """
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    scale = D ** -0.5
    qg = q.reshape(B, Sq, Hkv, g, D)
    kv_pos = jnp.arange(Skv)

    def block(qb, qpos):
        # qb: (B, bq, Hkv, g, D); qpos: (bq,) absolute positions
        s = _grouped_scores(qb, k) * scale            # (B,Hkv,g,bq,Skv)
        mask = jnp.ones((qb.shape[1], Skv), bool)
        if causal:
            mask &= qpos[:, None] >= kv_pos[None, :]
        if window:
            mask &= (qpos[:, None] - kv_pos[None, :]) < window
        mask = mask[None, None, None]                 # (1,1,1,bq,Skv)
        if kv_len is not None:
            kl = jnp.asarray(kv_len)
            if kl.ndim == 0:
                mask = mask & (kv_pos < kl)
            else:                                     # (B,) ragged lengths
                mask = mask & (kv_pos[None, :] < kl[:, None]
                               )[:, None, None, None]
        if kv_start is not None:
            ks = jnp.asarray(kv_start)
            if ks.ndim == 0:
                mask = mask & (kv_pos >= ks)
            else:                                     # (B,) ragged starts
                mask = mask & (kv_pos[None, :] >= ks[:, None]
                               )[:, None, None, None]
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
        return o.astype(q.dtype)

    if Sq <= block_q:
        out = block(qg, q_offset + jnp.arange(Sq))
    else:
        nb = -(-Sq // block_q)
        pad = nb * block_q - Sq
        qp = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        qp = qp.reshape(B, nb, block_q, Hkv, g, D).transpose(1, 0, 2, 3, 4, 5)
        pos = (q_offset + jnp.arange(nb * block_q)).reshape(nb, block_q)

        def body(_, xs):
            qb, pb = xs
            return None, block(qb, pb)

        _, out = jax.lax.scan(body, None, (qp, pos))
        Dv = out.shape[-1]
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, nb * block_q, Hkv, g, Dv)
        out = out[:, :Sq]
    return out.reshape(B, Sq, H, -1)


def triangular_attention(q, k, v, *, window: int = 0) -> jax.Array:
    """Causal attention with static block skipping: query block i only
    computes scores against keys [lo_i, (i+1)*bq) where lo_i honors the
    sliding window.  Unrolled (static shapes per block) — ~2x fewer
    attention FLOPs than ``chunked_attention`` for full causal, more for
    windowed.  Used by the perf pass."""
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    block_q = min(1024, Sq)
    assert Sq % block_q == 0 and Sq == Skv, "triangular path needs aligned blocks"
    nb = Sq // block_q
    scale = D ** -0.5
    qg = q.reshape(B, Sq, Hkv, g, D)
    outs = []
    for i in range(nb):
        hi = (i + 1) * block_q
        lo = 0
        if window:
            lo = max(0, (i * block_q + 1) - window)
            lo = (lo // block_q) * block_q          # align to blocks
        qb = qg[:, i * block_q:hi]
        kb, vb = k[:, lo:hi], v[:, lo:hi]
        qpos = jnp.arange(i * block_q, hi)
        kpos = jnp.arange(lo, hi)
        s = _grouped_scores(qb, kb) * scale
        mask = qpos[:, None] >= kpos[None, :]
        if window:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vb.astype(jnp.float32))
        outs.append(o.astype(q.dtype).reshape(B, block_q, H, -1))
    return jnp.concatenate(outs, axis=1)


# --------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# --------------------------------------------------------------------------

def attention_fwd(p: dict, cfg: ModelConfig, x, positions, *,
                  causal: bool = True, window: int = 0,
                  mode: str = "flash", xkv=None, rope: bool = True,
                  return_kv: bool = False):
    """Full-sequence attention.  Returns (out, (k, v) if return_kv).

    mode="flash" (default): custom-vjp flash attention — O(S.D)
    residuals, static causal block skipping.  mode="naive": the
    reference softmax path (tests / ablation baseline)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, xkv)
    if rope:
        sections = cfg.mrope_sections if cfg.mrope else None
        q = L.apply_rope(q, positions, cfg.rope_theta, sections)
        k = L.apply_rope(k, positions, cfg.rope_theta, sections)
    if mode == "flash":
        o = flash_attention(q, k, v, causal=causal, window=window)
    else:
        o = chunked_attention(q, k, v, causal=causal, window=window)
    o = shard(o, "batch", None, "model", None)
    out = o.reshape(B, S, -1) @ p["w_o"]
    if return_kv:
        return out, (k, v)
    return out


# --------------------------------------------------------------------------
# single-token decode against a KV cache
# --------------------------------------------------------------------------

def attention_decode(p: dict, cfg: ModelConfig, x, cache_k, cache_v,
                     pos, *, window: int = 0, xkv=None, rope: bool = True,
                     rope_pos=None):
    """x: (B, 1, d).  cache_k/v: (B, S_cache, Hkv, D) where S_cache is
    ``window`` for sliding-window archs (ring buffer) else max_seq.
    pos: scalar int32 — cache slot index (absolute sequence position) —
    or a (B,) vector of per-sequence positions (continuous batching:
    each slot is at its own depth in the sequence).
    rope_pos: rotary position if it differs from the slot index (VLM:
    M-RoPE text positions restart after the patch grid)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1
    q, k, v = _project_qkv(p, cfg, x, xkv)
    if rope:
        rp = pos if rope_pos is None else rope_pos
        if per_slot:
            posv = jnp.reshape(rp, (B, 1))
        else:
            posv = jnp.full((B, 1), rp, jnp.int32)
        sections = cfg.mrope_sections if cfg.mrope else None
        if sections is not None:
            posv = jnp.broadcast_to(posv, (3, B, 1))
        q = L.apply_rope(q, posv, cfg.rope_theta, sections)
        k = L.apply_rope(k, posv, cfg.rope_theta, sections)
    S_cache = cache_k.shape[1]
    slot = jnp.where(window > 0, pos % S_cache, pos) if window else pos
    if per_slot:
        upd = jax.vmap(
            lambda ck, cv, kk, vv, s: (
                jax.lax.dynamic_update_slice(ck, kk, (s, 0, 0)),
                jax.lax.dynamic_update_slice(cv, vv, (s, 0, 0))))
        cache_k, cache_v = upd(cache_k, cache_v, k, v, slot)
    else:
        cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))
    kv_len = jnp.minimum(pos + 1, S_cache)
    # ring buffers hold an unordered window; softmax is order-invariant
    # so masking by validity is sufficient (rope already encoded order).
    o = chunked_attention(q, cache_k, cache_v, causal=False,
                          kv_len=kv_len)
    out = o.reshape(B, 1, -1) @ p["w_o"]
    return out, cache_k, cache_v


def _chunk_page_targets(pos_offset, C, n_valid, page_size, block_table):
    """Scatter targets for one prefill chunk: position ``pos_offset + i``
    lands in page ``bt[pos // page_size]`` at offset ``pos % page_size``;
    pad positions (``i >= n_valid`` — chunk shapes are bucketed for jit
    reuse) land on the scratch page 0, which no live sequence reads."""
    pos = pos_offset + jnp.arange(C, dtype=jnp.int32)
    valid = jnp.arange(C) < n_valid
    page = jnp.where(valid, block_table.reshape(-1)[pos // page_size], 0)
    return pos, page, pos % page_size


def paged_prefill_attention(p: dict, cfg: ModelConfig, x, pool_k, pool_v,
                            pos_offset, n_valid, block_tables, *,
                            window: int = 0):
    """One prompt chunk of a single sequence, straight into the paged
    KV pool — the admission path of the unified token-budget step.

    x: (1, C, d) chunk activations (positions ``pos_offset ..
    pos_offset + C``, of which the first ``n_valid`` are real prompt
    tokens and the rest jit-bucketing pads).  pool_k/pool_v:
    (n_pages, page_size, Hkv, D) — the layer's slice of the global
    pool.  block_tables: (1, max_pages) int32 covering at least
    positions [0, pos_offset + n_valid).

    Each position's k/v is scattered into its absolute-position page
    (pads to the scratch page 0), then the chunk's queries attend
    causally over the gathered page set via ``chunked_attention``'s
    ``q_offset``/``kv_len`` masking — numerically the paged decode
    path applied C positions at a time, so no contiguous prefix cache
    (and no graft) ever exists.  The per-position outputs (and hence
    per-position logits upstream) are exact for EVERY chunk position,
    not just the last: speculative draft-verify replays a chunk of
    draft tokens mid-decode and reads all C next-token predictions
    from one pass."""
    B, C, _ = x.shape
    ps = pool_k.shape[1]
    q, k, v = _project_qkv(p, cfg, x)
    pos, page, off = _chunk_page_targets(pos_offset, C, n_valid, ps,
                                         block_tables)
    posv = jnp.broadcast_to(pos[None], (B, C))
    q = L.apply_rope(q, posv, cfg.rope_theta)
    k = L.apply_rope(k, posv, cfg.rope_theta)
    pool_k = pool_k.at[page, off].set(k[0].astype(pool_k.dtype))
    pool_v = pool_v.at[page, off].set(v[0].astype(pool_v.dtype))
    kg = pool_k[block_tables.reshape(-1)].reshape(1, -1, *pool_k.shape[2:])
    vg = pool_v[block_tables.reshape(-1)].reshape(1, -1, *pool_v.shape[2:])
    kg = shard(kg, "batch", None, "model", None)
    vg = shard(vg, "batch", None, "model", None)
    o = chunked_attention(q, kg, vg, causal=True, q_offset=pos_offset,
                          window=window, kv_len=pos_offset + n_valid)
    out = o.reshape(B, C, -1) @ p["w_o"]
    return out, pool_k, pool_v


def paged_attention_decode(p: dict, cfg: ModelConfig, x, pool_k, pool_v,
                           pos, block_tables, *, window: int = 0,
                           rope: bool = True, rope_pos=None):
    """Single-token decode against a paged KV pool.

    x: (B, 1, d).  pool_k/pool_v: (n_pages, page_size, Hkv, D) — the
    layer's slice of the global page pool.  pos: (B,) absolute write
    positions.  block_tables: (B, max_pages) int32 — entry j of row b is
    the page holding positions [j*page_size, (j+1)*page_size) of
    sequence b; unused entries point at the scratch page 0.

    The new k/v land in page ``bt[b, pos//page_size]`` at offset
    ``pos % page_size``; attention gathers the table's pages back into
    position order, masked to ``pos+1`` valid positions (and, for
    sliding-window archs, lower-bounded at ``pos+1-window`` — pages here
    hold absolute positions, not a ring buffer).  Freshly allocated
    pages may hold a stale sequence's KV beyond ``pos``; the kv_len mask
    keeps the overwrite-before-read guarantee of the contiguous layout.
    """
    B = x.shape[0]
    ps = pool_k.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    q, k, v = _project_qkv(p, cfg, x)
    if rope:
        rp = pos if rope_pos is None else rope_pos
        posv = jnp.reshape(rp, (B, 1))
        sections = cfg.mrope_sections if cfg.mrope else None
        if sections is not None:
            posv = jnp.broadcast_to(posv, (3, B, 1))
        q = L.apply_rope(q, posv, cfg.rope_theta, sections)
        k = L.apply_rope(k, posv, cfg.rope_theta, sections)
    page = jnp.take_along_axis(block_tables, (pos // ps)[:, None],
                               axis=1)[:, 0]                   # (B,)
    off = pos % ps
    pool_k = pool_k.at[page, off].set(k[:, 0].astype(pool_k.dtype))
    pool_v = pool_v.at[page, off].set(v[:, 0].astype(pool_v.dtype))
    from repro.kernels import ops              # local: models stay
    # importable without touching the Pallas toolchain at module load
    if window == 0 and ops.paged_kernel_ok():
        # the Pallas kernel streams pages by block-table lookup in the
        # DMA index_map — no contiguous gather is ever materialized
        o = ops.paged_decode_attention(q[:, 0], pool_k, pool_v,
                                       block_tables, pos + 1)[:, None]
    else:
        # CPU lowering / sliding window: gather the tables back into
        # position order and reuse the masked reference attention
        kg = pool_k[block_tables]            # (B, max_pages, ps, Hkv, D)
        vg = pool_v[block_tables]
        kg = shard(kg.reshape(B, -1, *pool_k.shape[2:]),
                   "batch", None, "model", None)
        vg = shard(vg.reshape(B, -1, *pool_v.shape[2:]),
                   "batch", None, "model", None)
        kv_start = jnp.maximum(pos + 1 - window, 0) if window else None
        o = chunked_attention(q, kg, vg, causal=False, kv_len=pos + 1,
                              kv_start=kv_start)
    out = o.reshape(B, 1, -1) @ p["w_o"]
    return out, pool_k, pool_v


# --------------------------------------------------------------------------
# MLA forward (expanded for train/prefill, absorbed for decode)
# --------------------------------------------------------------------------

def _mla_qkv(p, cfg, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ql = L.rmsnorm(p["q_norm"], x @ p["w_dq"], cfg.norm_eps)
    q = (ql @ p["w_uq"]).reshape(B, S, H, qk_head)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    dkv = x @ p["w_dkv"]
    ckv, k_rope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    ckv = L.rmsnorm(p["kv_norm"], ckv, cfg.norm_eps)
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return q_nope, q_rope, ckv, k_rope[:, :, 0, :]


def mla_fwd(p: dict, cfg: ModelConfig, x, positions, *, mode="flash",
            return_cache: bool = False):
    """Expanded MLA for train/prefill: reconstruct per-head k/v."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, cfg, x, positions)
    k_nope = (ckv @ p["w_uk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (ckv @ p["w_uv"]).reshape(B, S, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, m.qk_rope_head_dim))],
        axis=-1)
    q = shard(q, "batch", None, "model", None)
    k = shard(k, "batch", None, "model", None)
    o = (flash_attention(q, k, v, causal=True) if mode == "flash"
         else chunked_attention(q, k, v, causal=True))
    out = o.reshape(B, S, -1) @ p["w_o"]
    if return_cache:
        return out, (ckv, k_rope)
    return out


def mla_decode(p: dict, cfg: ModelConfig, x, cache_ckv, cache_krope, pos):
    """Absorbed MLA decode [arXiv:2412.19437 §2.1.1]: the k up-projection
    is folded into the query and the v up-projection into the output, so
    attention runs directly in the compressed (kv_lora_rank + rope) space
    — the cache stores only (ckv, k_rope) per token."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1                   # (B,) continuous-batching path
    posv = (jnp.reshape(pos, (B, 1)) if per_slot
            else jnp.full((B, 1), pos, jnp.int32))
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, cfg, x, posv)
    # cache update
    if per_slot:
        upd = jax.vmap(lambda c, u, s: jax.lax.dynamic_update_slice(
            c, u, (s, 0)))
        cache_ckv = upd(cache_ckv, ckv, pos)
        cache_krope = upd(cache_krope, k_rope, pos)
    else:
        cache_ckv = jax.lax.dynamic_update_slice(cache_ckv, ckv, (0, pos, 0))
        cache_krope = jax.lax.dynamic_update_slice(cache_krope, k_rope,
                                                   (0, pos, 0))
    kv_pos = jnp.arange(cache_ckv.shape[1])
    if per_slot:
        valid = kv_pos[None, :] <= pos[:, None]          # (B, S)
    else:
        valid = jnp.broadcast_to(kv_pos[None, :] <= pos,
                                 (B, cache_ckv.shape[1]))
    out = _mla_absorbed_attend(p, cfg, q_nope, q_rope, cache_ckv,
                               cache_krope, valid).astype(x.dtype)
    return out @ p["w_o"], cache_ckv, cache_krope


def _mla_absorbed_attend(p, cfg, q_nope, q_rope, ckv_seq, krope_seq, valid):
    """Absorbed MLA attention core.  q_nope/q_rope: (B,Sq,H,*);
    ckv_seq: (B,S,r); krope_seq: (B,S,rope_d); valid: (B,S) bool
    (broadcast over queries) or (B,Sq,S) per-query (the chunked-prefill
    causal mask).  Returns the flattened per-head context
    (B, Sq, H*v_head_dim) in f32 (the caller applies w_o)."""
    m = cfg.mla
    H = cfg.n_heads
    B, Sq = q_nope.shape[:2]
    # absorb w_uk into q: (B,1,H,nope) x (lora,H,nope) -> (B,1,H,lora)
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s_lat = jnp.einsum("bqhr,bkr->bhqk", q_lat,
                       ckv_seq.astype(jnp.float32))
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                        krope_seq.astype(jnp.float32))
    s = (s_lat + s_rope) * scale
    mask = (valid[:, None, None, :] if valid.ndim == 2
            else valid[:, None, :, :])
    s = jnp.where(mask, s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqk,bkr->bqhr", prob, ckv_seq.astype(jnp.float32))
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bqhr,rhd->bqhd", ctx, w_uv.astype(jnp.float32))
    return o.reshape(B, Sq, -1)


def mla_paged_prefill(p: dict, cfg: ModelConfig, x, pool_ckv, pool_krope,
                      pos_offset, n_valid, block_tables):
    """One prompt chunk straight into the paged MLA latent cache (see
    ``paged_prefill_attention`` for the chunk/page layout): the chunk's
    (ckv, k_rope) land in their absolute-position pages, pads on the
    scratch page, and attention runs the absorbed decode path with a
    per-query causal mask — C positions at a time, every position's
    output exact (the speculative verify pass reads all of them, not
    just the final chunk position)."""
    B, C, _ = x.shape
    ps = pool_ckv.shape[1]
    pos, page, off = _chunk_page_targets(pos_offset, C, n_valid, ps,
                                         block_tables)
    posv = jnp.broadcast_to(pos[None], (B, C))
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, cfg, x, posv)
    pool_ckv = pool_ckv.at[page, off].set(ckv[0].astype(pool_ckv.dtype))
    pool_krope = pool_krope.at[page, off].set(
        k_rope[0].astype(pool_krope.dtype))
    bt = block_tables.reshape(-1)
    ckv_seq = shard(pool_ckv[bt].reshape(1, -1, pool_ckv.shape[-1]),
                    "batch", None, "model")
    krope_seq = shard(pool_krope[bt].reshape(1, -1, pool_krope.shape[-1]),
                      "batch", None, "model")
    kv_pos = jnp.arange(ckv_seq.shape[1])
    valid = ((kv_pos[None, None, :] <= pos[None, :, None])
             & (kv_pos[None, None, :] < pos_offset + n_valid))
    out = _mla_absorbed_attend(p, cfg, q_nope, q_rope, ckv_seq,
                               krope_seq, valid).astype(x.dtype)
    return out @ p["w_o"], pool_ckv, pool_krope


def mla_paged_decode(p: dict, cfg: ModelConfig, x, pool_ckv, pool_krope,
                     pos, block_tables):
    """Absorbed MLA decode against a paged latent cache.

    pool_ckv: (n_pages, page_size, kv_lora_rank); pool_krope:
    (n_pages, page_size, qk_rope_head_dim).  pos: (B,) absolute write
    positions; block_tables: (B, max_pages) int32 (see
    ``paged_attention_decode`` for the page layout and the
    overwrite-before-read argument)."""
    B = x.shape[0]
    ps = pool_ckv.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    posv = jnp.reshape(pos, (B, 1))
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, cfg, x, posv)
    page = jnp.take_along_axis(block_tables, (pos // ps)[:, None],
                               axis=1)[:, 0]
    off = pos % ps
    pool_ckv = pool_ckv.at[page, off].set(ckv[:, 0].astype(pool_ckv.dtype))
    pool_krope = pool_krope.at[page, off].set(
        k_rope[:, 0].astype(pool_krope.dtype))
    ckv_seq = pool_ckv[block_tables].reshape(B, -1, pool_ckv.shape[-1])
    krope_seq = pool_krope[block_tables].reshape(B, -1, pool_krope.shape[-1])
    kv_pos = jnp.arange(ckv_seq.shape[1])
    valid = kv_pos[None, :] <= pos[:, None]
    out = _mla_absorbed_attend(p, cfg, q_nope, q_rope, ckv_seq,
                               krope_seq, valid).astype(x.dtype)
    return out @ p["w_o"], pool_ckv, pool_krope
