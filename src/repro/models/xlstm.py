"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, chunkwise
parallel) and sLSTM (scalar memory, sequential scan).

mLSTM cell per head (dqk = dv = d_inner / n_heads):
    m_t = max(logsig(f~_t) + m_{t-1}, i~_t)
    C_t = e^{logsig(f~)+m_{t-1}-m_t} C_{t-1} + e^{i~-m_t} k_t v_t^T
    n_t = (same decays) n_{t-1} + e^{i~-m_t} k_t
    h_t = (C_t^T q_t) / max(|n_t . q_t|, e^{-m_t})
computed here in the stabilized chunkwise form (quadratic within chunks,
scan across chunk states).  sLSTM is inherently sequential (that is its
point in the paper) — a lax.scan over time; noted in the roofline
analysis as the non-parallelizable fraction of xlstm-1.3b.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models.ssm import causal_conv
from repro.models.pspec import shard

F32 = jnp.float32
_MFLOOR = -30.0            # numeric floor for the log-space stabilizer


def mlstm_dims(cfg: ModelConfig):
    x = cfg.xlstm
    d_inner = int(x.proj_factor_mlstm * cfg.d_model)
    dh = d_inner // cfg.n_heads
    return d_inner, cfg.n_heads, dh


# ==========================================================================
# mLSTM
# ==========================================================================

def init_mlstm_block(key, cfg: ModelConfig) -> dict:
    x = cfg.xlstm
    dt = L.dtype_of(cfg.param_dtype)
    d = cfg.d_model
    d_inner, nh, dh = mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "norm": L.init_rmsnorm(d, dt),
        "w_up": L.dense_init(ks[0], (d, 2 * d_inner), dt),   # (main, gate)
        "conv_w": (jax.random.normal(ks[1], (x.d_conv, d_inner), F32)
                   * (1.0 / x.d_conv ** 0.5)).astype(dt),
        "conv_b": jnp.zeros((d_inner,), dt),
        # head-wise (block-diagonal) q/k/v projections, as in the official
        # xLSTM implementation: (nh, dh, dh) instead of (d_inner, d_inner)
        "w_q": (jax.random.normal(ks[2], (nh, dh, dh), F32) / dh ** 0.5).astype(dt),
        "w_k": (jax.random.normal(ks[3], (nh, dh, dh), F32) / dh ** 0.5).astype(dt),
        "w_v": (jax.random.normal(ks[4], (nh, dh, dh), F32) / dh ** 0.5).astype(dt),
        # scalar input/forget gate pre-activations per head
        "w_if": L.dense_init(ks[5], (d_inner, 2 * nh), dt),
        "b_if": jnp.concatenate([jnp.zeros((nh,)),
                                 3.0 * jnp.ones((nh,))]).astype(F32),
        "skip": jnp.ones((d_inner,), dt),
        "gn": L.init_rmsnorm(dh, dt),                        # per-head norm
        "w_down": L.dense_init(ks[6], (d_inner, d), dt),
    }


def mlstm_chunked(q, k, v, igate, fgate, chunk: int,
                  state: Optional[Tuple] = None):
    """q,k,v: (B,S,H,D); igate/fgate: (B,S,H) pre-activations.
    Returns (h (B,S,H,D), (C, n, m) final state)."""
    B, S, H, D = q.shape
    Lc = min(chunk, S)
    assert S % Lc == 0, (S, Lc)
    nc = S // Lc
    scale = D ** -0.5

    qc = q.reshape(B, nc, Lc, H, D).astype(F32) * scale
    kc = k.reshape(B, nc, Lc, H, D).astype(F32)
    vc = v.reshape(B, nc, Lc, H, D).astype(F32)
    ig = igate.reshape(B, nc, Lc, H).astype(F32)
    lf = jax.nn.log_sigmoid(fgate.reshape(B, nc, Lc, H).astype(F32))
    b = jnp.cumsum(lf, axis=2)                            # (B,nc,Lc,H)

    # intra-chunk log weights  Lw[t,s] = b_t - b_s + i_s  for s <= t
    bT = b.transpose(0, 1, 3, 2)                          # (B,nc,H,Lc)
    igT = ig.transpose(0, 1, 3, 2)
    Lw = bT[..., :, None] - bT[..., None, :] + igT[..., None, :]
    tri = jnp.tril(jnp.ones((Lc, Lc), bool))
    Lw = jnp.where(tri, Lw, -jnp.inf)

    if state is None:
        C0 = jnp.zeros((B, H, D, D), F32)
        n0 = jnp.zeros((B, H, D), F32)
        m0 = jnp.full((B, H), -jnp.inf, F32)
    else:
        C0, n0, m0 = (s.astype(F32) for s in state)

    def body(carry, inp):
        C, n, m = carry
        qb, kb, vb, bb, igb, Lwb = inp
        # bb: (B,Lc,H); Lwb: (B,H,Lc,Lc)
        intra_max = jnp.max(Lwb, axis=-1)                 # (B,H,Lc)
        inter = bb.transpose(0, 2, 1) + m[..., None]      # (B,H,Lc)
        mt = jnp.maximum(jnp.maximum(intra_max, inter), _MFLOOR)
        wI = jnp.exp(Lwb - mt[..., None])                 # (B,H,Lc,Lc)
        wX = jnp.exp(inter - mt)                          # (B,H,Lc)

        sc = jnp.einsum("blhd,bshd->bhls", qb, kb) * wI
        h_num = (jnp.einsum("bhls,bshd->blhd", sc, vb)
                 + jnp.einsum("blhd,bhde->blhe", qb, C)
                 * wX.transpose(0, 2, 1)[..., None])
        denom = (jnp.sum(sc, axis=-1)
                 + jnp.einsum("blhd,bhd->bhl", qb, n) * wX)  # (B,H,Lc)
        denom = jnp.maximum(jnp.abs(denom), jnp.exp(-mt))
        h = h_num / denom.transpose(0, 2, 1)[..., None]   # (B,Lc,H,D)

        # chunk-end state update
        bL = bb[:, -1]                                    # (B,H)
        st = bL[:, None, :] - bb + igb                    # (B,Lc,H)
        m_new = jnp.maximum(jnp.maximum(bL + m, jnp.max(st, axis=1)), _MFLOOR)
        wS = jnp.exp(st - m_new[:, None, :])              # (B,Lc,H)
        carry_w = jnp.exp(bL + m - m_new)                 # (B,H)
        C_new = (C * carry_w[..., None, None]
                 + jnp.einsum("bsh,bshd,bshe->bhde", wS, kb, vb))
        n_new = (n * carry_w[..., None]
                 + jnp.einsum("bsh,bshd->bhd", wS, kb))
        return (C_new, n_new, m_new), h

    xs = (qc.swapaxes(0, 1), kc.swapaxes(0, 1), vc.swapaxes(0, 1),
          b.swapaxes(0, 1), ig.swapaxes(0, 1), Lw.swapaxes(0, 1))
    (C, n, m), hs = jax.lax.scan(body, (C0, n0, m0), xs)
    h = hs.swapaxes(0, 1).reshape(B, S, H, D)
    return h, (C, n, m)


def mlstm_block_fwd(p: dict, cfg: ModelConfig, x, *, return_state=False):
    d_inner, nh, dh = mlstm_dims(cfg)
    B, S, d = x.shape
    xn = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    x_main, z = jnp.split(xn @ p["w_up"], 2, axis=-1)
    x_main = shard(x_main, "batch", None, "model")
    conv = jax.nn.silu(
        causal_conv(x_main, p["conv_w"], p["conv_b"]).astype(F32)).astype(x.dtype)
    convh = conv.reshape(B, S, nh, dh)
    mainh = x_main.reshape(B, S, nh, dh)
    q = jnp.einsum("bshd,hde->bshe", convh, p["w_q"])
    k = jnp.einsum("bshd,hde->bshe", convh, p["w_k"])
    v = jnp.einsum("bshd,hde->bshe", mainh, p["w_v"])
    gif = (x_main @ p["w_if"]).astype(F32) + p["b_if"]
    ig, fg = jnp.split(gif, 2, axis=-1)                   # (B,S,nh)
    h, state = mlstm_chunked(q, k, v, ig, fg, chunk=min(256, S))
    h = L.rmsnorm(p["gn"], h.astype(x.dtype), cfg.norm_eps)
    h = h.reshape(B, S, d_inner) + conv * p["skip"]
    h = h * jax.nn.silu(z.astype(F32)).astype(x.dtype)
    out = x + h @ p["w_down"]
    if return_state:
        C, n, m = state
        return out, {"C": C, "n": n, "m": m,
                     "conv": x_main[:, -(cfg.xlstm.d_conv - 1):]}
    return out


def mlstm_block_decode(p: dict, cfg: ModelConfig, x, cache: dict):
    """Sequential mLSTM step.  x: (B, 1, d)."""
    d_inner, nh, dh = mlstm_dims(cfg)
    B = x.shape[0]
    xn = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    x_main, z = jnp.split(xn @ p["w_up"], 2, axis=-1)     # (B,1,d_inner)
    win = jnp.concatenate([cache["conv"].astype(x.dtype), x_main], axis=1)
    conv = (jnp.einsum("bkc,kc->bc", win.astype(F32),
                       p["conv_w"].astype(F32)) + p["conv_b"].astype(F32))
    conv = jax.nn.silu(conv)[:, None, :].astype(x.dtype)
    convh = conv.reshape(B, nh, dh)
    mainh = x_main.reshape(B, nh, dh)
    q = jnp.einsum("bhd,hde->bhe", convh, p["w_q"]).astype(F32) * dh ** -0.5
    k = jnp.einsum("bhd,hde->bhe", convh, p["w_k"]).astype(F32)
    v = jnp.einsum("bhd,hde->bhe", mainh, p["w_v"]).astype(F32)
    gif = (x_main @ p["w_if"]).astype(F32)[:, 0] + p["b_if"]
    ig, fg = jnp.split(gif, 2, axis=-1)                   # (B,nh)
    lf = jax.nn.log_sigmoid(fg)
    C, n, m = (cache["C"].astype(F32), cache["n"].astype(F32),
               cache["m"].astype(F32))
    m_new = jnp.maximum(jnp.maximum(lf + m, ig), _MFLOOR)
    wf = jnp.exp(lf + m - m_new)
    wi = jnp.exp(ig - m_new)
    C = (C * wf[..., None, None]
         + wi[..., None, None] * k[..., None] * v[..., None, :])
    n = n * wf[..., None] + wi[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)),
                      jnp.exp(-m_new))[..., None]
    h = (num / den).astype(x.dtype)
    h = L.rmsnorm(p["gn"], h, cfg.norm_eps).reshape(B, 1, d_inner)
    h = h + conv * p["skip"]
    h = h * jax.nn.silu(z.astype(F32)).astype(x.dtype)
    out = x + h @ p["w_down"]
    return out, {"C": C, "n": n, "m": m_new, "conv": win[:, 1:]}


# ==========================================================================
# sLSTM
# ==========================================================================

def init_slstm_block(key, cfg: ModelConfig) -> dict:
    x = cfg.xlstm
    dt = L.dtype_of(cfg.param_dtype)
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    d_ff = int(x.proj_factor_slstm * d)
    ks = jax.random.split(key, 4)
    return {
        "norm": L.init_rmsnorm(d, dt),
        "conv_w": (jax.random.normal(ks[0], (x.d_conv, d), F32)
                   * (1.0 / x.d_conv ** 0.5)).astype(dt),
        "conv_b": jnp.zeros((d,), dt),
        "w_gates": L.dense_init(ks[1], (d, 4 * d), dt),   # z, i, f, o streams
        # block-diagonal recurrent weights per head: (4, nh, dh, dh)
        "r_gates": (jax.random.normal(ks[2], (4, nh, dh, dh), F32)
                    * (1.0 / dh ** 0.5)).astype(dt),
        "b_gates": jnp.concatenate(
            [jnp.zeros((2 * d,)), 3.0 * jnp.ones((d,)),
             jnp.zeros((d,))]).astype(F32),
        "gn": L.init_rmsnorm(dh, dt),
        "up": L.init_swiglu(ks[3], d, d_ff, dt),
    }


def _slstm_cell(Wx, r_gates, h_prev, c_prev, n_prev, m_prev, nh, dh):
    """One sLSTM step.  Wx: (B, 4, nh, dh) input pre-activations (+bias)."""
    B = Wx.shape[0]
    hp = h_prev.reshape(B, nh, dh)
    rec = jnp.einsum("ghde,bhd->gbhe", r_gates.astype(F32), hp)
    pre = Wx.transpose(1, 0, 2, 3) + rec                  # (4,B,nh,dh)
    zt = jnp.tanh(pre[0])
    it = pre[1]                                           # log-space gates
    lf = jax.nn.log_sigmoid(pre[2])
    ot = jax.nn.sigmoid(pre[3])
    m_new = jnp.maximum(lf + m_prev, it)
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(lf + m_prev - m_new)
    c = f_ * c_prev + i_ * zt
    n = jnp.maximum(f_ * n_prev + i_, 1e-6)
    h = ot * c / n
    return h.reshape(B, nh * dh), c, n, m_new


def _slstm_gate_inputs(p, cfg, xn, conv):
    """Project the (raw, conv) streams into the 4 gate pre-activations."""
    d = cfg.d_model
    wg = p["w_gates"].reshape(d, 4, d)
    Wz = xn @ wg[:, 0]
    Wi = conv @ wg[:, 1]
    Wf = conv @ wg[:, 2]
    Wo = xn @ wg[:, 3]
    Wx = jnp.stack([Wz, Wi, Wf, Wo], axis=-2).astype(F32)  # (..., 4, d)
    return Wx + p["b_gates"].reshape(4, d)


def slstm_block_fwd(p: dict, cfg: ModelConfig, x, *, return_state=False):
    d = cfg.d_model
    nh, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    B, S, _ = x.shape
    xn = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    conv = jax.nn.silu(
        causal_conv(xn, p["conv_w"], p["conv_b"]).astype(F32)).astype(x.dtype)
    Wx = _slstm_gate_inputs(p, cfg, xn, conv)             # (B,S,4,d)
    Wx = Wx.reshape(B, S, 4, nh, dh)

    h0 = jnp.zeros((B, d), F32)
    c0 = jnp.zeros((B, nh, dh), F32)
    n0 = jnp.full((B, nh, dh), 1e-6, F32)
    m0 = jnp.zeros((B, nh, dh), F32)

    def step(carry, wx):
        h, c, n, m = carry
        h, c, n, m = _slstm_cell(wx, p["r_gates"], h, c, n, m, nh, dh)
        return (h, c, n, m), h

    (h, c, n, m), hs = jax.lax.scan(step, (h0, c0, n0, m0), Wx.swapaxes(0, 1))
    hs = hs.swapaxes(0, 1).astype(x.dtype)                # (B,S,d)
    hs = L.rmsnorm(p["gn"], hs.reshape(B, S, nh, dh),
                   cfg.norm_eps).reshape(B, S, d)
    out = x + L.swiglu(p["up"], hs)
    if return_state:
        return out, {"h": h, "c": c, "n": n, "m": m,
                     "conv_win": xn[:, -(cfg.xlstm.d_conv - 1):]}
    return out


def slstm_block_decode(p: dict, cfg: ModelConfig, x, cache: dict):
    d = cfg.d_model
    nh, dh = cfg.n_heads, d // cfg.n_heads
    B = x.shape[0]
    xn = L.rmsnorm(p["norm"], x, cfg.norm_eps)[:, 0]      # (B, d)
    win = jnp.concatenate([cache["conv_win"].astype(x.dtype), xn[:, None]], 1)
    conv = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", win.astype(F32), p["conv_w"].astype(F32))
        + p["conv_b"].astype(F32)).astype(x.dtype)
    Wx = _slstm_gate_inputs(p, cfg, xn, conv)             # (B,4,d)
    Wx = Wx.reshape(B, 4, nh, dh)
    h, c, n, m = _slstm_cell(Wx, p["r_gates"], cache["h"].astype(F32),
                             cache["c"].astype(F32), cache["n"].astype(F32),
                             cache["m"].astype(F32), nh, dh)
    hs = L.rmsnorm(p["gn"], h.astype(x.dtype).reshape(B, 1, nh, dh),
                   cfg.norm_eps).reshape(B, 1, d)
    out = x + L.swiglu(p["up"], hs)
    return out, {"h": h, "c": c, "n": n, "m": m, "conv_win": win[:, 1:]}
