"""Mixture-of-Experts MLP with expert-parallel sharding.

Two dispatch strategies (selectable, compared in EXPERIMENTS.md §Perf):

* ``einsum`` — GShard-style grouped one-hot dispatch/combine matmuls
  [arXiv:2006.16668].  TPU-friendly, but the dispatch einsum costs
  ``2·G·E·C·d`` FLOPs per group — real compute burned on one-hot zeros.
* ``scatter`` — capacity-bounded scatter/gather dispatch: tokens are
  placed into their (expert, slot) row via a static-shape scatter-add,
  O(T·d) data movement and ZERO matmul FLOPs.  The beyond-paper
  optimization used after the perf pass.

Experts are sharded over the "expert" logical axis (-> mesh "model");
tokens arrive sharded over "batch" (-> "data"), so GSPMD materializes
the all-to-all on the dispatched activations.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models.pspec import shard

CAPACITY_FACTOR = 1.25


def _ceil4(x: int) -> int:
    """Expert capacities round up to a multiple of 4 (min 4)."""
    return max(4, -(-int(x) // 4) * 4)


def init_moe(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    dt = L.dtype_of(cfg.param_dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], (d, m.n_experts), jnp.float32),
        "w_gate": L.dense_init(ks[1], (m.n_experts, d, m.d_expert), dt),
        "w_up": L.dense_init(ks[2], (m.n_experts, d, m.d_expert), dt),
        "w_down": L.dense_init(ks[3], (m.n_experts, m.d_expert, d), dt),
    }
    if m.n_shared_experts:
        p["shared"] = L.init_swiglu(
            ks[4], d, m.n_shared_experts * m.d_shared_expert, dt)
    return p


def _route(p, cfg, x2d):
    """x2d: (T, d) -> (probs (T,k), experts (T,k), aux_loss, full_probs)."""
    m = cfg.moe
    logits = (x2d.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.experts_per_token)   # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)     # renormalize
    # GShard/Switch load-balance loss: E * sum_e f_e * P_e
    assign = jax.nn.one_hot(top_e, m.n_experts).sum(1)         # (T, E)
    f = assign.mean(0) / m.experts_per_token
    P = probs.mean(0)
    aux = m.n_experts * jnp.sum(f * P) * m.router_aux_loss
    return top_p, top_e, aux, probs


def _capacity(cfg, tokens_per_group: int) -> int:
    m = cfg.moe
    c = int(tokens_per_group * m.experts_per_token * CAPACITY_FACTOR / m.n_experts)
    return _ceil4(c)


def initial_capacity(cfg: ModelConfig, n_tokens: int,
                     factor: float = 2.0) -> int:
    """First guess for the dynamic drop-free serving-prefill capacity:
    ``factor`` x the mean per-expert load (``T*k/E``), rounded up to a
    multiple of 4 — the engines double it on overflow, so this only
    sets where the (few) compiled capacity buckets start."""
    m = cfg.moe
    mean = n_tokens * m.experts_per_token / m.n_experts
    return min(_ceil4(mean * factor), n_tokens)


def _expert_ffn(p, xe):
    """xe: (..., E, C, d) -> gated FFN per expert (weights stacked on E)."""
    h = jnp.einsum("...ecd,edf->...ecf", xe, p["w_gate"])
    u = jnp.einsum("...ecd,edf->...ecf", xe, p["w_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(xe.dtype) * u
    return jnp.einsum("...ecf,efd->...ecd", h, p["w_down"])


def moe_fwd(p: dict, cfg: ModelConfig, x, *, dispatch: str = "einsum",
            group_size: int = 2048, drop_free: bool = False,
            capacity=None) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d).  Returns (y, aux_loss).

    drop_free: size expert capacity so NO token is ever dropped.  The
    serving paths (prefill/decode) require this: a token dropped in one
    phrasing of the batch but not another changes logits, breaking
    greedy determinism and prefill+decode == full-forward equivalence.
    Training keeps the capacity-bounded (dropping) GShard behavior for
    throughput.

    capacity: optional static bound on drop-free expert capacity.  The
    static drop-free worst case (``C = G``: every token routed to ONE
    expert) inflates the dispatch tensors ~``E/k``x over the typical
    load; serving prefill instead passes a small per-batch bound and
    RETRIES with a larger one if it overflowed.  When set (drop_free
    only), the returned aux is the number of overflowed routings as
    float32 — 0.0 means no token was dropped and the result is
    token-exact with the unbounded path (zero-padded expert slots
    contribute exact zeros, so shrinking C does not change the math);
    nonzero means the caller must re-run with a larger bound before
    trusting the logits."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    x2d = x.reshape(T, d)
    top_p, top_e, aux, _ = _route(p, cfg, x2d)

    # grouping: keep >=16 groups so the group axis of the dispatched
    # tensor shards over the data axis — without this the dispatch output
    # is all-gathered across data (§Perf B3: 3.2x on deepseek train).
    # Decode-scale token counts (T < 16*G) keep a single group: splitting
    # tiny batches regressed decode 4x (§Perf C5).
    if T >= 16 * group_size:
        G = group_size
        while G > 1 and (T % G or T // G < 16):
            G //= 2
        G = max(G, 1)
    else:
        G = T
    n = T // G
    # worst case every token routes to ONE expert: C = G slots suffice
    C_exact = _ceil4(G)
    if drop_free:
        C = C_exact if capacity is None else min(_ceil4(capacity), C_exact)
    else:
        C = _capacity(cfg, G)
    xg = x2d.reshape(n, G, d)
    eg = top_e.reshape(n, G, m.experts_per_token)
    pg = top_p.reshape(n, G, m.experts_per_token)
    pos = _slot_positions(eg, m.n_experts)
    if drop_free and capacity is not None:
        # overflow channel replaces the balance loss (serving never
        # trains): number of routings past the capacity bound
        aux = jnp.sum(pos >= C).astype(jnp.float32)

    if dispatch == "einsum":
        y = _dispatch_einsum(p, cfg, xg, eg, pg, pos, C)
    elif dispatch == "scatter":
        y = _dispatch_scatter(p, cfg, xg, eg, pg, pos, C)
    else:
        raise ValueError(dispatch)
    y = y.reshape(B, S, d)

    if m.n_shared_experts:
        y = y + L.swiglu(p["shared"], x)
    return y, aux


def _slot_positions(eg, n_experts):
    """Position of each (token, k) routing within its expert's slots.
    eg: (n, G, k) -> (n, G, k) int32 cumulative index per expert."""
    n, G, k = eg.shape
    flat = eg.reshape(n, G * k)
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)   # (n, G*k, E)
    pos = jnp.cumsum(onehot, axis=1) - 1                        # 0-based
    pos = jnp.take_along_axis(pos, flat[..., None], axis=-1)[..., 0]
    return pos.reshape(n, G, k)


def _dispatch_einsum(p, cfg, xg, eg, pg, pos, C):
    """GShard one-hot dispatch.  xg: (n, G, d); pos: (n, G, k) expert
    slot of each routing (from ``_slot_positions``)."""
    m = cfg.moe
    n, G, d = xg.shape
    keep = pos < C
    e_oh = jax.nn.one_hot(eg, m.n_experts, dtype=xg.dtype)     # (n,G,k,E)
    c_oh = jax.nn.one_hot(pos, C, dtype=xg.dtype)              # (n,G,k,C)
    disp = jnp.einsum("ngke,ngkc->ngec", e_oh * keep[..., None], c_oh)
    # combine weights in the activation dtype: f32 here would upcast the
    # dispatched tensor and DOUBLE the cross-device bytes (§Perf B3)
    comb = jnp.einsum("ngke,ngkc,ngk->ngec",
                      e_oh, c_oh, (pg * keep).astype(xg.dtype))
    xe = jnp.einsum("ngec,ngd->necd", disp, xg)                # (n,E,C,d)
    # groups over "batch"(data), experts over "expert"(model): without the
    # group-axis constraint GSPMD all-gathers xe across data (§Perf B3)
    xe = shard(xe, "batch", "expert", None, None)
    he = _expert_ffn(p, xe)
    he = shard(he, "batch", "expert", None, None)
    return jnp.einsum("ngec,necd->ngd", comb, he)


def _dispatch_scatter(p, cfg, xg, eg, pg, pos, C):
    """Scatter/gather dispatch: zero matmul FLOPs in routing.
    pos: (n, G, k) expert slot of each routing."""
    m = cfg.moe
    n, G, d = xg.shape
    k = m.experts_per_token
    keep = pos < C
    # flat slot id per routing decision; dropped tokens go to a trash row
    slot = eg * C + jnp.clip(pos, 0, C - 1)                    # (n, G, k)
    slot = jnp.where(keep, slot, m.n_experts * C)
    xrep = jnp.broadcast_to(xg[:, :, None, :], (n, G, k, d))

    def per_group(slots, xr):
        buf = jnp.zeros((m.n_experts * C + 1, d), xg.dtype)
        buf = buf.at[slots.reshape(-1)].add(xr.reshape(-1, d))
        return buf[:-1]

    xe = jax.vmap(per_group)(slot, xrep).reshape(n, m.n_experts, C, d)
    xe = shard(xe, "batch", "expert", None, None)
    he = _expert_ffn(p, xe)
    he = shard(he, "batch", "expert", None, None)
    he = he.reshape(n, m.n_experts * C, d)

    def per_group_combine(h, slots, w):
        got = h[jnp.clip(slots.reshape(-1), 0, m.n_experts * C - 1)]
        got = got.reshape(G, k, d) * w[..., None].astype(h.dtype)
        return got.sum(1)

    w = jnp.where(keep, pg, 0.0)
    return jax.vmap(per_group_combine)(he, slot, w)
