"""Logical-axis sharding annotations.

Model code annotates activations/params with *logical* axis names
("batch", "model", "expert", ...).  The launch layer installs rules that
map logical names to physical mesh axes; outside any rules (unit tests,
single device) the annotations are no-ops.

Divisibility-aware: a logical annotation is dropped for a tensor dim
whose size is not divisible by the mapped mesh-axis size (e.g. 15 query
heads cannot shard over model=16 — smollm falls back to replicated
heads; see DESIGN.md §4).
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis name -> tuple of physical mesh axes
DEFAULT_LOGICAL_MAP = {
    "batch": ("pod", "data"),      # pod dropped when absent from the mesh
    "fsdp": ("pod", "data"),       # optimizer/param state shards over the
                                   # pod axis too: deepseek train state is
                                   # 17.5 GB/dev on one pod, 9 GB/dev on two
    "model": ("model",),
    "expert": ("model",),
    "seq": ("model",),             # sequence sharding (MQA KV caches)
}

_STATE: dict = {"mesh": None, "map": None}


def set_mesh_rules(mesh: Optional[Mesh], logical_map=None) -> None:
    _STATE["mesh"] = mesh
    _STATE["map"] = dict(logical_map or DEFAULT_LOGICAL_MAP)


@contextmanager
def mesh_rules(mesh: Optional[Mesh], logical_map=None):
    prev = dict(_STATE)
    set_mesh_rules(mesh, logical_map)
    try:
        yield
    finally:
        _STATE.update(prev)


def current_mesh() -> Optional[Mesh]:
    return _STATE["mesh"]


def _resolve(logical: Optional[str], dim_size: int, mesh: Mesh):
    """Map a logical name to the subset of physical axes that exist in the
    mesh and evenly divide dim_size."""
    if logical is None:
        return None
    axes = _STATE["map"].get(logical, (logical,))
    present = [a for a in axes if a in mesh.shape]
    if not present:
        return None
    factor = math.prod(mesh.shape[a] for a in present)
    if dim_size % factor != 0:
        # drop trailing axes until it divides (or give up)
        while present:
            present.pop()
            factor = math.prod(mesh.shape[a] for a in present) if present else 1
            if present and dim_size % factor == 0:
                break
        if not present:
            return None
    return tuple(present) if len(present) > 1 else present[0]


def pspec_for(shape: Sequence[int], logical: Sequence[Optional[str]]) -> Optional[P]:
    mesh = _STATE["mesh"]
    if mesh is None:
        return None
    assert len(shape) == len(logical), (shape, logical)
    used: set = set()
    entries = []
    for size, name in zip(shape, logical):
        axes = _resolve(name, size, mesh)
        # a physical axis may appear only once in a PartitionSpec
        if axes is not None:
            flat = axes if isinstance(axes, tuple) else (axes,)
            if any(a in used for a in flat):
                axes = None
            else:
                used.update(flat)
        entries.append(axes)
    return P(*entries)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate an intermediate with logical sharding (no-op without rules)."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return x
    spec = pspec_for(x.shape, logical)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_count(logical: Optional[str], dim_size: int) -> int:
    """How many ways a dim of ``dim_size`` shards under ``logical`` with
    the installed rules (1 without rules, or when divisibility forces
    the replication fallback).  The serving engine reports per-device
    KV-pool and expert-dispatch accounting with this."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return 1
    axes = _resolve(logical, dim_size, mesh)
    if axes is None:
        return 1
    flat = axes if isinstance(axes, tuple) else (axes,)
    return math.prod(mesh.shape[a] for a in flat)


def named_sharding(shape, logical) -> Optional[NamedSharding]:
    mesh = _STATE["mesh"]
    if mesh is None:
        return None
    return NamedSharding(mesh, pspec_for(shape, logical))
