"""Common layers: norms, MLPs, embeddings, rotary embeddings (RoPE/M-RoPE).

Everything is functional: ``init_*`` returns a param dict, ``*_fwd``
applies it.  Norm/softmax math runs in fp32; matmuls in the activation
dtype (bf16 by default).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.pspec import shard


def dtype_of(name: str):
    return jnp.dtype(name)


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float = 1.0):
    """Truncated-normal fan-in init (what llama-family models use)."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    std = scale / (fan_in ** 0.5)
    return (std * jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


def norm(params: dict, x: jax.Array, eps: float) -> jax.Array:
    return layernorm(params, x, eps) if "bias" in params else rmsnorm(params, x, eps)


def init_norm(d: int, dtype, use_layernorm: bool = False) -> dict:
    return init_layernorm(d, dtype) if use_layernorm else init_rmsnorm(d, dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def init_swiglu(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def swiglu(params: dict, x: jax.Array) -> jax.Array:
    g = x @ params["w_gate"]
    u = x @ params["w_up"]
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard(h, "batch", None, "model") if h.ndim == 3 else h
    return h @ params["w_down"]


def init_gelu_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    """Bias'd GELU MLP (whisper / GPT-style)."""
    k1, k2 = jax.random.split(key)
    return {
        "w_up": dense_init(k1, (d_model, d_ff), dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": dense_init(k2, (d_ff, d_model), dtype),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params: dict, x: jax.Array) -> jax.Array:
    h = x @ params["w_up"] + params["b_up"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = shard(h, "batch", None, "model") if h.ndim == 3 else h
    return h @ params["w_down"] + params["b_down"]


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope_sections: Optional[tuple] = None) -> jax.Array:
    """Rotate ``x`` of shape (..., seq, heads, head_dim).

    positions: (batch, seq) int32 — or (3, batch, seq) for M-RoPE, where
    the leading axis is the (temporal, height, width) position triple
    [arXiv:2409.12191].  ``mrope_sections`` gives the split of the
    head_dim/2 frequency slots across the three position streams.
    """
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                 # (hd/2,)
    if positions.ndim == 3:                                    # M-RoPE
        assert mrope_sections is not None
        t, h, w = positions.astype(jnp.float32)
        ang_t = t[..., None] * freqs                           # (b, s, hd/2)
        ang_h = h[..., None] * freqs
        ang_w = w[..., None] * freqs
        st, sh, sw = mrope_sections
        assert st + sh + sw == head_dim // 2, (mrope_sections, head_dim)
        angles = jnp.concatenate(
            [ang_t[..., :st], ang_h[..., st:st + sh], ang_w[..., st + sh:]],
            axis=-1)
    else:
        angles = positions.astype(jnp.float32)[..., None] * freqs  # (b, s, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (b, s, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, d_model: int) -> jax.Array:
    """Whisper-style sinusoidal position table (n_pos, d_model)."""
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * (jnp.log(10000.0) / max(d_model // 2 - 1, 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# embedding / unembedding
# --------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype) -> jax.Array:
    return embed_init(key, (vocab, d_model), dtype)


def embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    out = jnp.take(table, tokens, axis=0)
    return shard(out, "batch", None, None)


def unembed(table_or_head: jax.Array, x: jax.Array, transpose: bool) -> jax.Array:
    """Project hidden states to vocab logits (fp32 for a stable softmax)."""
    w = table_or_head.astype(jnp.bfloat16)
    logits = jnp.einsum("bsd,vd->bsv" if transpose else "bsd,dv->bsv", x, w)
    return shard(logits.astype(jnp.float32), "batch", None, "model")
