"""Model substrate: functional JAX model definitions for every assigned
architecture family (dense / moe / hybrid / ssm / audio / vlm)."""
