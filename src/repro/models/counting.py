"""Parameter counting via jax.eval_shape over the real init (always
consistent with the actual model), with an analytic correction for MoE
active-parameter counts (MODEL_FLOPS = 6 * N_active * D)."""
from __future__ import annotations

import functools

import jax
import numpy as np

from repro.config import ModelConfig


@functools.lru_cache(maxsize=64)
def _shapes(cfg: ModelConfig, max_seq: int):
    from repro.models.transformer import init_params
    return jax.eval_shape(
        lambda k: init_params(k, cfg, max_seq=max_seq),
        jax.ShapeDtypeStruct((2,), np.uint32))


def count_params(cfg: ModelConfig, active_only: bool = False,
                 max_seq: int = 4096) -> int:
    tree = _shapes(cfg, max_seq)
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))
    if active_only and cfg.moe is not None:
        m = cfg.moe
        n_moe_layers = cfg.n_layers - m.n_dense_layers
        per_expert = 3 * cfg.d_model * m.d_expert
        total -= n_moe_layers * (m.n_experts - m.experts_per_token) * per_expert
    return total
