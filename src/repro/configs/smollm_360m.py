"""smollm-360m [dense] — llama-arch small model.
[hf:HuggingFaceTB/SmolLM-135M family, 360M variant]
32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    citation="hf:HuggingFaceTB/SmolLM-135M",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    head_dim=64,
    tie_embeddings=True,
)

REDUCED = CONFIG.with_(
    name="smollm-360m-reduced",
    n_layers=2, d_model=240, n_heads=3, n_kv_heads=1, d_ff=640,
    vocab_size=512, head_dim=80,
)
