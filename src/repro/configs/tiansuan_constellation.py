"""Constellation deployment: K=3 Baoyun-class satellites, 2 stations.

The paper's verification flew on the Tiansuan constellation — several
cloud-native satellites, not one.  Every spacecraft flies the same
ONBOARD payload (identical buses; ``configs/tiansuan_pair``), which is
what makes an inter-satellite handover token-exact: greedy decode from
a grafted KV snapshot continues identically on any peer.

The window geometry is deliberately asymmetric — satellite 0 is on a
plane with poor station visibility (one short pass where its peers get
dozens), which is the regime where contact planning and handover pay:
``serving.constellation.ConstellationScheduler`` moves satellite 0's
backlog to window-rich peers over the ISL instead of parking it until
the lone pass.  ``benchmarks/serving_throughput.py`` gates the
constellation replay against the K-independent-pairs comparator built
from the same numbers.
"""
from repro.configs.tiansuan_pair import ONBOARD

# Every satellite flies the onboard tier (homogeneous constellation).
SATELLITE = ONBOARD

CONSTELLATION = dict(
    n_satellites=3,
    n_stations=2,
    s_per_step=1.0,                   # shared tick (seconds per step)
    horizon_s=7200.0,                 # replay horizon
    # per-(satellite, station) window sets via
    # ContactSchedule.step_window_sets: satellite 0's plane sees a
    # station ~once per horizon; planes 1-2 every few minutes
    contact_duration_s=8.0,
    contacts_per_day=[12, 1200, 1200],
    schedule_seed=3,
    # contact planning + handover (serving.constellation)
    policy="value",                   # priority-to-value pass assignment
    handover_margin_ticks=64,         # peer must be this much sooner
    isl_mbps=100.0,                   # optical inter-satellite link
    # framed ARQ on both the downlink and the ISL (core.link): per-frame
    # CRC + NACK retransmission, bounded retries, failed payloads
    # re-enqueue — the same wire discipline as the pair deployment
    frame_bytes=1024,
    link_max_retries=8,
)

CONFIG = SATELLITE
REDUCED = SATELLITE
