"""whisper-tiny [audio] — encoder-decoder, conv frontend (STUB).
[arXiv:2212.04356]
4L (encoder) + 4L (decoder) d_model=384 6H d_ff=1536 vocab=51865.
The mel-spectrogram + conv feature extractor is a stub per the
assignment: input_specs() provides precomputed 1500 frame embeddings of
shape (batch, 1500, 384).  Absolute (sinusoidal) positions, pre-LN,
LayerNorm (not RMSNorm) — we keep RoPE off via rope_theta=0 sentinel
handled by the model builder (whisper uses learned/sinusoidal pos).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    citation="arXiv:2212.04356",
    n_layers=4,                    # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    qkv_bias=True,
    is_encoder_decoder=True,
    n_encoder_layers=4,
    n_audio_frames=1500,
)

REDUCED = CONFIG.with_(
    name="whisper-tiny-reduced",
    n_layers=2, n_encoder_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=64, n_audio_frames=96,
)
