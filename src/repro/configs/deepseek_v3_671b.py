"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed experts top-8, MTP.
[arXiv:2412.19437]
61L d_model=7168 128H d_ff=2048 (per expert) vocab=129280, MoE 256e top-8.
First 3 layers use a dense MLP (d_ff=18432); remaining 58 are MoE.
MLA: q_lora_rank=1536, kv_lora_rank=512, qk_nope=128, qk_rope=64, v=128.
The decode KV cache stores the compressed latent (512+64 per token),
which is what makes 32k/500k decode shapes feasible.
"""
from repro.config import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    citation="arXiv:2412.19437",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,                     # per routed expert
    vocab_size=129280,
    head_dim=128,
    use_mtp=True,
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=256,
        experts_per_token=8,
        d_expert=2048,
        n_shared_experts=1,
        d_shared_expert=2048,
        n_dense_layers=3,
        dense_d_ff=18432,
    ),
)

REDUCED = CONFIG.with_(
    name="deepseek-v3-671b-reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=512, head_dim=64,
    use_mtp=True,
    mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
                  qk_rope_head_dim=16, v_head_dim=32),
    moe=MoEConfig(n_experts=4, experts_per_token=2, d_expert=128,
                  n_shared_experts=1, d_shared_expert=128,
                  n_dense_layers=1, dense_d_ff=256),
)
