"""zamba2-7b [hybrid] — Mamba2 backbone + weight-shared attention blocks.
[arXiv:2411.15242]
81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
The 81 layers are Mamba2 blocks; a single weight-shared attention+MLP
block (32 heads, d_ff=14336) is interleaved every 6 Mamba2 blocks,
following the Zamba2 shared-block design.
"""
from repro.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    citation="arXiv:2411.15242",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    shared_attn_every=6,
)

REDUCED = CONFIG.with_(
    name="zamba2-7b-reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
    vocab_size=512, head_dim=64,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=64),
    shared_attn_every=2,
)
