"""qwen1.5-4b [dense] — QKV bias, full MHA (kv=20).
[hf:Qwen/Qwen1.5-0.5B family, 4B variant]
40L d_model=2560 20H (kv=20) d_ff=6912 vocab=151936.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    citation="hf:Qwen/Qwen1.5-0.5B",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
)

REDUCED = CONFIG.with_(
    name="qwen1.5-4b-reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
    vocab_size=512, head_dim=64,
)
