"""Assigned architecture configs (public-literature pool) + the paper's own
two-tier collaborative pair.  Each module defines CONFIG (exact assigned
numbers, citation in the docstring) and REDUCED (smoke-test variant).
"""
from repro.config import ARCH_IDS, get_config, get_reduced_config  # noqa: F401
