"""The paper's own configuration: the Tiansuan two-tier collaborative pair.

The paper deploys YOLOv3-tiny onboard (Baoyun, Raspberry-Pi-class payload)
and YOLOv3 on the ground.  Our assigned pool is transformer LMs, so the
pair becomes a (reduced, full) pair of the same family (DESIGN.md §2):
the onboard tier is a ~9M-param model sized for a Pi-class power budget,
the ground tier a ~6x larger model.  The cascade parameters mirror the
paper's deployment: confidence threshold gating, tile splitting, cloud
redundancy filtering, and the Baoyun link budget (Table 1).
"""
from repro.config import ModelConfig

# Onboard "satellite" tier — YOLOv3-tiny analogue (Pi-class budget).
ONBOARD = ModelConfig(
    name="tiansuan-onboard",
    family="dense",
    citation="this paper (YOLOv3-tiny analogue)",
    n_layers=4,
    d_model=192,
    n_heads=4,
    n_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    head_dim=48,
    tie_embeddings=True,
)

# Ground "cloud" tier — YOLOv3 analogue.
GROUND = ModelConfig(
    name="tiansuan-ground",
    family="dense",
    citation="this paper (YOLOv3 analogue)",
    n_layers=12,
    d_model=384,
    n_heads=8,
    n_kv_heads=4,
    d_ff=1024,
    vocab_size=512,
    head_dim=48,
    tie_embeddings=True,
)

# Deployment parameters (paper Table 1 + Section IV).
CASCADE = dict(
    confidence_metric="max_prob",     # posterior max, as in the paper
    confidence_threshold=0.62,        # calibrated in benchmarks/fig7_accuracy.py
    tile=64,                          # onboard tile splitting (DOTA frames)
    cloud_filter=True,                # redundancy (cloud-cover) filter
    uplink_mbps=1.0,                  # Table 1: 0.1~1 Mbps
    downlink_mbps=40.0,               # Table 1: >=40 Mbps
    orbital_altitude_km=500.0,        # Table 1
)

# Space-ground scheduling parameters (serving.scheduler): the onboard
# tier decodes through ground-station passes (overlap=True splits each
# pass into a transmit lane and a compute lane; the Pi's comm stack
# only claims comm_reserve_pages of KV for downlink staging, spilling
# just the sequences whose pages must cover it).  s_per_step is a
# Pi-class per-token decode latency for the ONBOARD tier; the ground
# tier is assumed always-on.  overlap=False restores the stop-the-world
# schedule (every pass preempts all decode — PR 3's behavior).
# prefill_budget_tokens bounds EVERY onboard tick (the engine's unified
# token-budget step chunks arriving prompts), so a long uplinked prompt
# can never freeze a pass's transmit lane for its whole length.
SCHEDULER = dict(
    s_per_step=0.35,                  # onboard decode seconds per token
    contact_duration_s=480.0,         # ~8 min LEO pass (ContactSchedule)
    contacts_per_day=6,
    escalate_threshold=0.62,          # cascade gate (CASCADE) reuse
    overlap=True,                     # transmit/compute lanes share a pass
    comm_reserve_pages=2,             # KV pages held for downlink staging
    delta_spill=True,                 # re-spills ship only dirtied pages
    prefill_budget_tokens=16,         # ContinuousEngine chunked-prefill
    #                                   budget: per-tick prompt tokens
    # fault tolerance (core.faults / framed TransmitLane): the downlink
    # is framed with per-frame CRC + NACK retransmission, and the
    # onboard scheduler checkpoints its full serving state so a
    # radiation-induced reboot resumes token-exactly from the last
    # checkpoint instead of recomputing the day's backlog.
    frame_bytes=1024,                 # downlink ARQ frame size
    link_max_retries=8,               # per-frame retry budget
    checkpoint_every=64,              # onboard ticks between checkpoints
    # speculative escalation (serving.speculative / engine draft-verify):
    # an escalated sequence downlinks only the ONBOARD tier's draft
    # token ids (payload_bytes_draft) and the GROUND tier verifies up to
    # draft_k of them per slot per tick in one chunked pass — greedy
    # token-exact with a raw re-decode at a fraction of the bytes.
    speculative=True,
    draft_k=8,                        # max drafts verified per pass
)

CONFIG = GROUND            # default arch when loaded via get_config
REDUCED = ONBOARD
