"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks, no separate FFN (d_ff=0).
[arXiv:2405.04517]
48L d_model=2048 4H vocab=50304.  Blocks are mLSTM (matrix memory,
proj_factor=2) with every 8th block an sLSTM (scalar memory,
proj_factor=4/3), the paper's ~7:1 ratio.
"""
from repro.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    citation="arXiv:2405.04517",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                        # blocks carry their own up/down proj
    vocab_size=50304,
    head_dim=512,
    xlstm=XLSTMConfig(slstm_every=8, proj_factor_mlstm=2.0,
                      proj_factor_slstm=1.3333, d_conv=4),
)

REDUCED = CONFIG.with_(
    name="xlstm-1.3b-reduced",
    n_layers=2, d_model=256, n_heads=2, n_kv_heads=2, d_ff=0,
    vocab_size=512, head_dim=128,
    xlstm=XLSTMConfig(slstm_every=2, proj_factor_mlstm=2.0,
                      proj_factor_slstm=1.3333, d_conv=4),
)
