"""qwen3-moe-30b-a3b [moe] — 128 experts, top-8.
[hf:Qwen/Qwen3-30B-A3B]
48L d_model=2048 32H (GQA kv=4) d_ff=768 (per expert) vocab=151936,
MoE 128e top-8.  Qwen3 uses head_dim=128 with QK-norm; d_ff is the
per-expert (moe) intermediate size.
"""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    citation="hf:Qwen/Qwen3-30B-A3B",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,                      # per-expert intermediate size
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    moe=MoEConfig(
        n_experts=128,
        experts_per_token=8,
        d_expert=768,
    ),
)

REDUCED = CONFIG.with_(
    name="qwen3-moe-30b-a3b-reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, head_dim=64,
    moe=MoEConfig(n_experts=4, experts_per_token=2, d_expert=128),
)
