"""granite-34b [dense] — llama-arch code model, MQA.
[arXiv:2405.04324]
88L d_model=6144 48H (GQA kv=1, i.e. multi-query) d_ff=24576 vocab=49152.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    citation="arXiv:2405.04324",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    mlp_type="gelu",
)

REDUCED = CONFIG.with_(
    name="granite-34b-reduced",
    n_layers=2, d_model=384, n_heads=6, n_kv_heads=1, d_ff=1024,
    vocab_size=512, head_dim=64,
)
