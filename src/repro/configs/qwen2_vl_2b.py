"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution; vision encoder STUB.
[arXiv:2409.12191]
28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
The ViT vision encoder + projector is a stub per the assignment:
input_specs() provides precomputed patch embeddings (batch, n_patches,
d_model) which the model interleaves ahead of the text tokens.  M-RoPE
splits each head_dim/2 rotary space into (temporal, height, width)
sections (16, 24, 24).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    citation="arXiv:2409.12191",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),
    n_patches=256,                 # default image budget per request
    tie_embeddings=True,
)

REDUCED = CONFIG.with_(
    name="qwen2-vl-2b-reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
    vocab_size=512, head_dim=64, n_patches=16,
    mrope_sections=(8, 12, 12),
)
