"""Configuration system for the repro framework.

Every assigned architecture is described by a :class:`ModelConfig`; input
shapes by :class:`ShapeSpec`.  Configs live in ``repro.configs.<arch>`` as
module-level ``CONFIG`` (full, exact numbers from the assignment table) and
``REDUCED`` (smoke-test variant: <=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


# --------------------------------------------------------------------------
# Block types understood by the model builder (repro.models.transformer).
#   attn      - GQA/MQA/MLA self-attention + dense MLP
#   attn_moe  - self-attention + mixture-of-experts MLP
#   mamba2    - Mamba2 selective-state-space block
#   mlstm     - xLSTM matrix-memory block
#   slstm     - xLSTM scalar-memory block
# Hybrids (zamba2) additionally use `shared_attn_every` to interleave a
# weight-shared attention block between SSM blocks.
# --------------------------------------------------------------------------

VALID_FAMILIES = ("dense", "moe", "hybrid", "ssm", "audio", "vlm")


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    experts_per_token: int
    d_expert: int                  # per-expert FFN hidden dim
    n_shared_experts: int = 0
    d_shared_expert: int = 0       # hidden dim of the shared expert(s)
    router_aux_loss: float = 0.01  # load-balance loss coefficient
    # number of leading layers that use a dense MLP instead of MoE
    n_dense_layers: int = 0
    dense_d_ff: int = 0


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention [arXiv:2412.19437]."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 block hyper-parameters [arXiv:2405.21060 via zamba2 2411.15242]."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256               # chunked-scan chunk length


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block hyper-parameters [arXiv:2405.04517]."""
    slstm_every: int = 8           # every k-th block is an sLSTM block (7:1 ratio)
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.3333
    d_conv: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # one of VALID_FAMILIES
    citation: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False          # Qwen3-style per-head RMSNorm on q/k
    rope_theta: float = 10_000.0
    mrope: bool = False            # Qwen2-VL multimodal RoPE
    mrope_sections: tuple = (16, 24, 24)   # (t, h, w) split of head_dim/2
    tie_embeddings: bool = False
    mlp_type: str = "swiglu"       # "swiglu" | "gelu" (GPT-BigCode/whisper)
    norm_eps: float = 1e-6
    # sliding-window attention (enables long_500k on quadratic archs)
    sliding_window: int = 0        # 0 -> full attention
    # family-specific sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # DeepSeek-V3 multi-token prediction head [arXiv:2412.19437 §2.2]
    use_mtp: bool = False
    mtp_weight: float = 0.3
    # hybrid (zamba2): apply a weight-shared attention block every k SSM blocks
    shared_attn_every: int = 0
    # audio (whisper): encoder-decoder
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500     # precomputed mel/conv frames (frontend stub)
    # vlm (qwen2-vl): number of precomputed patch embeddings per request
    n_patches: int = 0
    # dtype policy
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def __post_init__(self):
        if self.family not in VALID_FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.n_kv_heads and self.n_heads % self.n_kv_heads:
            raise ValueError(
                f"{self.name}: n_heads={self.n_heads} not divisible by "
                f"n_kv_heads={self.n_kv_heads}")

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # Parameter counting (used for roofline MODEL_FLOPS = 6*N*D)
    # ------------------------------------------------------------------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count; active_only counts only routed
        experts actually used per token (for MoE MODEL_FLOPS)."""
        from repro.models.counting import count_params
        return count_params(self, active_only=active_only)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # 'train' | 'prefill' | 'decode'

    def __post_init__(self):
        if self.kind not in ("train", "prefill", "decode"):
            raise ValueError(self.kind)


INPUT_SHAPES = {
    "train_4k":    ShapeSpec("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeSpec("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeSpec("long_500k",   524_288, 1,   "decode"),
}

ARCH_IDS = (
    "smollm-360m",
    "qwen3-moe-30b-a3b",
    "zamba2-7b",
    "granite-34b",
    "deepseek-v3-671b",
    "whisper-tiny",
    "xlstm-1.3b",
    "qwen1.5-4b",
    "qwen2-vl-2b",
    "granite-20b",
)


def get_config(arch: str) -> ModelConfig:
    """Load the full config for an assigned architecture id."""
    import importlib
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


def get_reduced_config(arch: str) -> ModelConfig:
    import importlib
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")
    return mod.REDUCED


def supports_shape(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    """Whether (arch, shape) is a supported pair (see DESIGN.md §6)."""
    if shape.name == "long_500k":
        # whisper's decoder is anchored to a 1500-frame encoder; a 500k
        # self-attention decode cache contradicts the architecture.
        return not cfg.is_encoder_decoder
    return True
