"""Byte / energy / latency accounting for the collaborative system —
what the paper reports as "90% data reduction" and "17% compute energy"
comes out of this ledger."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict


@dataclass
class Ledger:
    counters: Dict[str, float] = field(default_factory=dict)

    def add(self, key: str, value: float) -> None:
        self.counters[key] = self.counters.get(key, 0.0) + float(value)

    def get(self, key: str) -> float:
        return self.counters.get(key, 0.0)

    def ratio(self, num: str, den: str) -> float:
        d = self.get(den)
        return self.get(num) / d if d else float("nan")

    def summary(self) -> Dict[str, float]:
        out = dict(self.counters)
        raw = self.get("bytes_bentpipe_baseline")
        if raw:
            out["data_reduction"] = 1.0 - self.get("bytes_downlinked") / raw
        esc = self.get("items_escalated")
        tot = self.get("items_total")
        if "items_total" in self.counters:
            # an empty batch escalates nothing, not NaN of something
            out["escalation_rate"] = esc / tot if tot else 0.0
        return out
