"""Onboard redundancy filtering (paper §II/IV): 80-90% of raw EO data
over southwest China is invalid due to cloud cover; discarding cloudy /
low-information tiles BEFORE inference and downlink is where the bulk of
the paper's 90% data reduction comes from (Figure 6).

Two filters, composable:
  * cloud filter — clouds are bright and low-texture: mean brightness
    above ``bright_thresh`` AND local variance below ``texture_thresh``.
  * redundancy filter — near-duplicate tiles (60% of remote-sensing
    images are highly similar [paper §II]): tiles whose downsampled
    signature matches a previously seen signature are dropped.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class CloudFilterConfig:
    bright_thresh: float = 0.72
    texture_thresh: float = 0.012
    sig_grid: int = 4            # signature resolution for dedup
    sig_tol: float = 0.035       # L-inf tolerance for "duplicate"


def cloud_mask(tiles: jax.Array, cfg: CloudFilterConfig = CloudFilterConfig()):
    """tiles: (N, t, t, C) in [0,1].  True = cloudy (drop)."""
    lum = jnp.mean(tiles.astype(jnp.float32), axis=-1)      # (N, t, t)
    mean_b = jnp.mean(lum, axis=(1, 2))
    var_t = jnp.var(lum, axis=(1, 2))
    return (mean_b > cfg.bright_thresh) & (var_t < cfg.texture_thresh)


def tile_signature(tiles: jax.Array, grid: int) -> jax.Array:
    """Downsampled luminance signature (N, grid*grid)."""
    N, t, _, _ = tiles.shape
    lum = jnp.mean(tiles.astype(jnp.float32), axis=-1)
    s = t // grid
    sig = lum[:, :grid * s, :grid * s].reshape(N, grid, s, grid, s)
    return sig.mean(axis=(2, 4)).reshape(N, -1)


def redundancy_mask(tiles: jax.Array,
                    cfg: CloudFilterConfig = CloudFilterConfig()):
    """True = near-duplicate of an EARLIER tile in the batch (drop).
    O(N^2) signature comparison — N is the per-pass tile count."""
    sig = tile_signature(tiles, cfg.sig_grid)                # (N, G)
    d = jnp.max(jnp.abs(sig[:, None, :] - sig[None, :, :]), axis=-1)
    earlier = jnp.tril(jnp.ones(d.shape[:2], bool), k=-1)
    return jnp.any((d < cfg.sig_tol) & earlier, axis=1)


def filter_tiles(tiles: jax.Array,
                 cfg: CloudFilterConfig = CloudFilterConfig()):
    """Returns (keep_mask (N,), stats dict).  keep = not cloudy and not
    redundant."""
    cloudy = cloud_mask(tiles, cfg)
    dup = redundancy_mask(tiles, cfg)
    keep = ~(cloudy | dup)
    n = tiles.shape[0]
    stats = {
        "n_tiles": n,
        "cloud_rate": jnp.mean(cloudy.astype(jnp.float32)),
        "dup_rate": jnp.mean(dup.astype(jnp.float32)),
        "filter_rate": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return keep, stats
