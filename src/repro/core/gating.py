"""Confidence-threshold gating (paper §IV): high confidence -> downlink
the compact result; low confidence -> escalate the raw payload to the
ground tier."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import confidence as C


@dataclass(frozen=True)
class ConfidenceGate:
    metric: str = "max_prob"
    threshold: float = 0.62

    def decide(self, logits: jax.Array, vocab: int | None = None) -> dict:
        """Returns {"escalate": bool (...,), "confidence": f32, "argmax"}."""
        vocab = vocab or logits.shape[-1]
        m = C.confidence_metrics(logits)
        conf = C.score(m, self.metric, vocab)
        return {"escalate": conf < self.threshold,
                "confidence": conf,
                "argmax": m["argmax"]}


def calibrate_threshold(confidences: np.ndarray, correct: np.ndarray,
                        budget_fraction: float) -> float:
    """Pick the threshold that escalates at most ``budget_fraction`` of
    items, preferring to escalate the least-confident ones (matches how
    the paper tunes its deployment to the downlink budget)."""
    order = np.sort(confidences)
    k = int(np.floor(budget_fraction * len(order)))
    if k <= 0:
        return float(order[0]) - 1e-6          # escalate nothing
    if k >= len(order):
        return float(order[-1]) + 1e-6         # escalate everything
    return float(0.5 * (order[k - 1] + order[k]))


def accuracy_with_gate(onboard_correct: np.ndarray, ground_correct: np.ndarray,
                       escalate: np.ndarray) -> float:
    """System accuracy: ground tier answers escalated items, onboard
    answers the rest."""
    return float(np.mean(np.where(escalate, ground_correct, onboard_correct)))
