"""Onboard image splitting (paper §IV): high-resolution EO frames exceed
the satellite's compute budget, so frames are split into fixed-size
tiles before in-orbit inference.  Works on (H, W, C) frames and batches
thereof; pure JAX so it fuses into the onboard preprocessing graph."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def split_frame(frame: jax.Array, tile: int) -> jax.Array:
    """(H, W, C) -> (n_tiles, tile, tile, C); H, W padded up to tile."""
    H, W, C = frame.shape
    Hp = -(-H // tile) * tile
    Wp = -(-W // tile) * tile
    f = jnp.pad(frame, ((0, Hp - H), (0, Wp - W), (0, 0)))
    f = f.reshape(Hp // tile, tile, Wp // tile, tile, C)
    return f.transpose(0, 2, 1, 3, 4).reshape(-1, tile, tile, C)


def merge_tiles(tiles: jax.Array, H: int, W: int) -> jax.Array:
    """Inverse of split_frame (drops padding)."""
    n, t, _, C = tiles.shape
    nh, nw = -(-H // t), -(-W // t)
    f = tiles.reshape(nh, nw, t, t, C).transpose(0, 2, 1, 3, 4)
    return f.reshape(nh * t, nw * t, C)[:H, :W]


def tile_grid(H: int, W: int, tile: int) -> Tuple[int, int]:
    return -(-H // tile), -(-W // tile)


def split_batch(frames: jax.Array, tile: int) -> jax.Array:
    """(B, H, W, C) -> (B * n_tiles, tile, tile, C)."""
    out = jax.vmap(lambda f: split_frame(f, tile))(frames)
    return out.reshape(-1, tile, tile, frames.shape[-1])
