"""The paper's contribution: satellite-ground collaborative inference.

Pipeline (paper §IV, Figure 5):
    EO frames -> tiling.split -> filtering.cloud_filter -> onboard tier
    -> confidence gate -> {downlink results | escalate raw payload}
    -> ground tier -> merged results
with byte-accurate link accounting (Table 1) and the energy model
(Tables 2-3)."""
from repro.core.cascade import CollaborativeEngine, CascadeConfig  # noqa
from repro.core.confidence import confidence_metrics               # noqa
from repro.core.gating import ConfidenceGate                       # noqa
from repro.core.link import LinkModel, ContactSchedule             # noqa
from repro.core.energy import EnergyModel                          # noqa
