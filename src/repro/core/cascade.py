"""The satellite-ground collaborative inference engine (paper §IV).

Generic over tiers: an onboard (cheap) model and a ground (accurate)
model, each a callable ``batch -> logits``.  Per item:

    1. onboard tier runs; the confidence gate scores its posterior;
    2. confident items downlink ONLY the compact result (16 B/item);
    3. low-confidence items downlink the raw payload (optionally int8-
       quantized — beyond-paper) and are re-answered by the ground tier;
    4. the ledger accounts bytes vs the bent-pipe baseline (downlink
       everything raw), energy (Tables 2-3) and link time (Table 1).

Works for EO-tile classification (the paper's case study, see
benchmarks/) and for LM serving (examples/collaborative_inference.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import EnergyModel
from repro.core.gating import ConfidenceGate
from repro.core.link import LinkModel, payload_bytes_raw, payload_bytes_result
from repro.core.telemetry import Ledger


@dataclass(frozen=True)
class CascadeConfig:
    gate: ConfidenceGate = ConfidenceGate()
    link: LinkModel = LinkModel()
    energy: EnergyModel = EnergyModel()
    onboard_s_per_item: float = 0.35      # YOLOv3-tiny on a Pi-class board
    quantize_payload: bool = False        # int8 payload compression (ours)
    item_dtype_bytes: int = 1             # raw EO tile bytes per element


@dataclass
class CascadeResult:
    predictions: np.ndarray               # final per-item predictions
    escalated: np.ndarray                 # bool mask
    confidence: np.ndarray
    ledger: Ledger = field(default_factory=Ledger)


class CollaborativeEngine:
    def __init__(self, onboard_fn: Callable, ground_fn: Callable,
                 cfg: CascadeConfig = CascadeConfig()):
        self.onboard_fn = onboard_fn
        self.ground_fn = ground_fn
        self.cfg = cfg

    def run(self, batch, item_shape, *,
            ground_available: bool = True) -> CascadeResult:
        """batch: whatever the tier callables consume; item_shape: shape
        of ONE raw item (for byte accounting)."""
        cfg = self.cfg
        ledger = Ledger()

        onboard_logits = np.asarray(self.onboard_fn(batch), np.float32)
        n = onboard_logits.shape[0]
        decision = cfg.gate.decide(jnp.asarray(onboard_logits))
        escalate = np.asarray(decision["escalate"])
        conf = np.asarray(decision["confidence"], np.float32)
        preds = np.asarray(decision["argmax"], np.int64)

        if not ground_available:
            escalate = np.zeros_like(escalate)

        # ---- byte accounting -------------------------------------------
        raw_item = payload_bytes_raw(1, item_shape, cfg.item_dtype_bytes)
        if cfg.quantize_payload:
            # int8 + one f32 scale per row (beyond-paper, kernels/int8_quant)
            raw_item = raw_item // cfg.item_dtype_bytes + 4
        n_esc = int(escalate.sum())
        bytes_results = payload_bytes_result(n - n_esc)
        bytes_raw = n_esc * raw_item
        bytes_baseline = n * payload_bytes_raw(1, item_shape,
                                               cfg.item_dtype_bytes)
        ledger.add("items_total", n)
        ledger.add("items_escalated", n_esc)
        ledger.add("bytes_downlinked", bytes_results + bytes_raw)
        ledger.add("bytes_results", bytes_results)
        ledger.add("bytes_raw_escalated", bytes_raw)
        ledger.add("bytes_bentpipe_baseline", bytes_baseline)
        ledger.add("downlink_s",
                   cfg.link.downlink_time_s(bytes_results + bytes_raw))
        ledger.add("downlink_s_bentpipe",
                   cfg.link.downlink_time_s(bytes_baseline))

        # ---- energy accounting -----------------------------------------
        ledger.add("energy_compute_j",
                   cfg.energy.inference_energy_j(n, cfg.onboard_s_per_item))
        ledger.add("energy_comm_j", cfg.energy.comm_energy_j(
            cfg.link.downlink_time_s(bytes_results + bytes_raw)))

        # ---- ground tier on escalated items ----------------------------
        if n_esc and ground_available:
            idx = np.nonzero(escalate)[0]
            sub = self._subset_batch(batch, idx)
            ground_logits = np.asarray(self.ground_fn(sub), np.float32)
            preds[idx] = ground_logits.argmax(-1)

        return CascadeResult(predictions=preds, escalated=escalate,
                             confidence=conf, ledger=ledger)

    @staticmethod
    def _subset_batch(batch, idx):
        if isinstance(batch, dict):
            return {k: v[idx] for k, v in batch.items()}
        return batch[idx]
