"""Onboard energy model — the paper's measured power budget.

Table 2 (Baoyun, W): electrical 1.47, propulsion 7.00, guidance 5.43,
avionics 4.81, comm 5.43, payloads 26.93, total 51.07.
Table 3 (payloads, W): camera 0.09, occultation 6.26, tribology 5.68,
mems 0.95, adsbs 6.12, raspberry pi (compute) 8.78.

The paper's headline: computing (the Pi) is ~17% of total onboard
energy; payloads are ~53%; the Pi is ~33% of payload energy.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.telemetry import Ledger

TABLE2_W: Dict[str, float] = {
    "electrical": 1.47,
    "propulsion": 7.00,
    "guidance": 5.43,
    "avionics": 4.81,
    "comm": 5.43,
    "payloads": 26.93,
}

TABLE3_W: Dict[str, float] = {
    "camera": 0.09,
    "occultation": 6.26,
    "tribology": 5.68,
    "mems": 0.95,
    "adsbs": 6.12,
    "raspberry_pi": 8.78,
}


@dataclass(frozen=True)
class EnergyModel:
    subsystem_w: Dict[str, float] = field(default_factory=lambda: dict(TABLE2_W))
    payload_w: Dict[str, float] = field(default_factory=lambda: dict(TABLE3_W))
    compute_key: str = "raspberry_pi"
    comm_key: str = "comm"

    @property
    def total_w(self) -> float:
        return sum(self.subsystem_w.values())

    @property
    def payload_total_w(self) -> float:
        return sum(self.payload_w.values())

    def compute_share_of_total(self) -> float:
        """Paper: ~17%."""
        return self.payload_w[self.compute_key] / self.total_w

    def compute_share_of_payload(self) -> float:
        """Paper: ~33%."""
        return self.payload_w[self.compute_key] / self.payload_total_w

    def payload_share_of_total(self) -> float:
        """Paper: ~53%."""
        return self.subsystem_w["payloads"] / self.total_w

    # ---- activity-based accounting for the cascade simulator ----------
    def inference_energy_j(self, n_items: int, s_per_item: float) -> float:
        return self.payload_w[self.compute_key] * n_items * s_per_item

    def comm_energy_j(self, tx_seconds: float) -> float:
        return self.subsystem_w[self.comm_key] * tx_seconds

    def energy_budget_j(self, horizon_s: float) -> float:
        return self.total_w * horizon_s


class FleetEnergy:
    """Per-satellite energy/byte metering for a constellation replay.

    Every Baoyun-class satellite flies the same bus, so ONE
    ``EnergyModel`` (Tables 2/3) is metered into one telemetry
    ``Ledger`` per spacecraft — "equal energy/byte budget" comparisons
    between replays are then checkable per satellite, not just
    fleet-wide.  Compute charges follow the pair scheduler's
    convention (one inference item per decode tick, whatever the batch
    width); comm charges cover both ground downlink seconds and
    inter-satellite-link seconds, with the byte streams kept in
    separate counters (``bytes_downlinked`` vs ``bytes_isl``)."""

    def __init__(self, n_satellites: int,
                 model: Optional[EnergyModel] = None):
        if n_satellites < 1:
            raise ValueError("FleetEnergy needs at least one satellite")
        self.model = model or EnergyModel()
        self.ledgers: List[Ledger] = [Ledger() for _ in range(n_satellites)]

    def charge_compute(self, sat: int, n_items: int,
                       s_per_item: float) -> None:
        led = self.ledgers[sat]
        led.add("energy_compute_j",
                self.model.inference_energy_j(n_items, s_per_item))
        led.add("decode_ticks", 1)

    def charge_downlink(self, sat: int, tx_seconds: float,
                        nbytes: float) -> None:
        led = self.ledgers[sat]
        led.add("energy_comm_j", self.model.comm_energy_j(tx_seconds))
        led.add("bytes_downlinked", nbytes)
        led.add("downlink_s", tx_seconds)

    def charge_isl(self, sat: int, tx_seconds: float,
                   nbytes: float) -> None:
        led = self.ledgers[sat]
        led.add("energy_comm_j", self.model.comm_energy_j(tx_seconds))
        led.add("bytes_isl", nbytes)
        led.add("isl_s", tx_seconds)

    def satellite(self, sat: int) -> Ledger:
        return self.ledgers[sat]

    def energy_j(self, sat: int) -> float:
        led = self.ledgers[sat]
        return led.get("energy_compute_j") + led.get("energy_comm_j")

    def within_budget(self, horizon_s: float) -> bool:
        """Every satellite within the bus's whole-horizon budget."""
        cap = self.model.energy_budget_j(horizon_s)
        return all(self.energy_j(k) <= cap
                   for k in range(len(self.ledgers)))

    def totals(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for led in self.ledgers:
            for k, v in led.counters.items():
                out[k] = out.get(k, 0.0) + v
        return out
