"""Space-ground link model (paper Table 1 + §II).

Baoyun: 500±50 km orbit, uplink 0.1–1 Mbps, downlink ≥40 Mbps; the
downlink is only available during ground-station contact windows, and
packet loss on the downlink can be severe (one mission lost 80% of
packets [paper ref 12]).  Deterministic PRNG — every test reproduces.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

SECONDS_PER_DAY = 86_400.0


@dataclass(frozen=True)
class LinkModel:
    uplink_mbps: float = 1.0
    downlink_mbps: float = 40.0
    packet_loss: float = 0.05          # fraction of packets lost (retried)
    packet_bytes: int = 1024
    orbital_altitude_km: float = 500.0

    @property
    def orbital_period_s(self) -> float:
        # Kepler: T = 2*pi*sqrt(a^3/mu), a = R_e + h
        mu = 3.986004418e14
        a = (6371.0 + self.orbital_altitude_km) * 1e3
        return 2.0 * np.pi * np.sqrt(a ** 3 / mu)

    def downlink_time_s(self, nbytes: float) -> float:
        """Expected transfer time incl. loss-retransmit overhead."""
        eff = self.downlink_mbps * 1e6 / 8.0 * (1.0 - self.packet_loss)
        return nbytes / eff

    def uplink_time_s(self, nbytes: float) -> float:
        eff = self.uplink_mbps * 1e6 / 8.0 * (1.0 - self.packet_loss)
        return nbytes / eff

    def deliver(self, nbytes: int, rng: np.random.Generator) -> Tuple[int, int]:
        """Simulate packetized delivery.  Returns (delivered_packets,
        retransmitted_packets)."""
        n_pkts = -(-nbytes // self.packet_bytes)
        retrans = int(rng.binomial(n_pkts, self.packet_loss))
        return n_pkts, retrans


@dataclass(frozen=True)
class ContactSchedule:
    """Ground-station visibility: a LEO satellite sees a given station
    for ~8 minutes, a handful of passes per day."""
    link: LinkModel = LinkModel()
    contact_duration_s: float = 480.0
    contacts_per_day: int = 6
    seed: int = 0

    def windows(self, horizon_s: float) -> List[Tuple[float, float]]:
        """Deterministic pseudo-random contact windows over a horizon."""
        rng = np.random.default_rng(self.seed)
        period = SECONDS_PER_DAY / self.contacts_per_day
        out = []
        t = 0.0
        while t < horizon_s:
            start = t + float(rng.uniform(0.2, 0.8)) * (
                period - self.contact_duration_s)
            out.append((start, min(start + self.contact_duration_s,
                                   horizon_s)))
            t += period
        return out

    def in_contact(self, t: float, horizon_s: float = SECONDS_PER_DAY) -> bool:
        return any(a <= t < b for a, b in self.windows(horizon_s))

    def next_window(self, t: float, horizon_s: float = SECONDS_PER_DAY):
        for a, b in self.windows(horizon_s):
            if b > t:
                return (max(a, t), b)
        return None

    def downlink_capacity_bytes(self, horizon_s: float) -> float:
        """Total bytes deliverable over the horizon."""
        total_s = sum(b - a for a, b in self.windows(horizon_s))
        return total_s * self.link.downlink_mbps * 1e6 / 8.0 * (
            1.0 - self.link.packet_loss)

    def step_windows(self, s_per_step: float,
                     horizon_s: float) -> List[Tuple[int, int]]:
        """Contact windows quantized to engine decode-step ticks
        [start_step, end_step) — the clock base the preemptive scheduler
        runs on (``serving.scheduler``).  A window shorter than one step
        still claims the tick it lands in: the downlink pass always
        preempts at least one decode step."""
        out = []
        for a, b in self.windows(horizon_s):
            if b <= a:
                continue         # start past the horizon, end clamped to
                #                  it: zero-capacity, not a real pass
            lo = int(a // s_per_step)
            hi = max(int(-(-b // s_per_step)), lo + 1)
            out.append((lo, hi))
        return out


class TransmitLane:
    """The downlink half of the overlapped contact pipeline.

    A FIFO of queued payloads drained *incrementally* against a per-tick
    byte budget, so a scheduler can interleave one decode step with one
    tick of transmission instead of holding the compute for a whole
    pass.  A payload larger than one tick's budget carries its partial
    progress across ticks (and across windows — an unfinished head
    simply waits for the next pass).

    ``tick(budget)`` returns the items whose transmission *completed*
    this tick, in FIFO order.  Determinism: same enqueues + same budgets
    => same completion ticks and byte ledger.
    """

    def __init__(self):
        self._q: List[list] = []          # [item, remaining_bytes]
        self.bytes_sent = 0.0
        self.n_completed = 0
        self.n_partial_ticks = 0          # ticks ending mid-payload

    def enqueue(self, item, nbytes: float) -> None:
        self._q.append([item, float(nbytes)])

    def __len__(self) -> int:
        return len(self._q)

    def pending_bytes(self) -> float:
        return sum(rem for _, rem in self._q)

    def pending_items(self) -> List:
        return [item for item, _ in self._q]

    def clear(self) -> List:
        """Drop the backlog (horizon exhausted); returns the items."""
        out = self.pending_items()
        self._q.clear()
        return out

    def tick(self, budget_bytes: float) -> List:
        """Transmit up to ``budget_bytes`` off the FIFO head; returns
        the items fully delivered this tick."""
        done = []
        remaining = float(budget_bytes)
        while self._q and self._q[0][1] <= remaining:
            item, nbytes = self._q.pop(0)
            remaining -= nbytes
            self.bytes_sent += nbytes
            self.n_completed += 1
            done.append(item)
        if self._q and remaining > 0.0:
            self._q[0][1] -= remaining
            self.bytes_sent += remaining
            self.n_partial_ticks += 1
        return done


def payload_bytes_result(n_items: int, classes: int = 1) -> int:
    """Compact inference result: class id + confidence + bbox-ish tuple
    per item (16 bytes, generous)."""
    return 16 * n_items * max(classes, 1)


def payload_bytes_raw(n_items: int, item_shape, dtype_bytes: int = 1) -> int:
    n = 1
    for d in item_shape:
        n *= d
    return n_items * n * dtype_bytes
