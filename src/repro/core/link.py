"""Space-ground link model (paper Table 1 + §II).

Baoyun: 500±50 km orbit, uplink 0.1–1 Mbps, downlink ≥40 Mbps; the
downlink is only available during ground-station contact windows, and
packet loss on the downlink can be severe (one mission lost 80% of
packets [paper ref 12]).  Deterministic PRNG — every test reproduces.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib
from collections import deque
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Tuple

import numpy as np

SECONDS_PER_DAY = 86_400.0


@dataclass(frozen=True)
class LinkModel:
    uplink_mbps: float = 1.0
    downlink_mbps: float = 40.0
    packet_loss: float = 0.05          # fraction of packets lost (retried)
    packet_bytes: int = 1024
    orbital_altitude_km: float = 500.0

    @property
    def orbital_period_s(self) -> float:
        # Kepler: T = 2*pi*sqrt(a^3/mu), a = R_e + h
        mu = 3.986004418e14
        a = (6371.0 + self.orbital_altitude_km) * 1e3
        return 2.0 * np.pi * np.sqrt(a ** 3 / mu)

    def downlink_time_s(self, nbytes: float) -> float:
        """Expected transfer time incl. loss-retransmit overhead."""
        eff = self.downlink_mbps * 1e6 / 8.0 * (1.0 - self.packet_loss)
        return nbytes / eff

    def uplink_time_s(self, nbytes: float) -> float:
        eff = self.uplink_mbps * 1e6 / 8.0 * (1.0 - self.packet_loss)
        return nbytes / eff

    def deliver(self, nbytes: int, rng: np.random.Generator) -> Tuple[int, int]:
        """Simulate packetized delivery.  Returns (delivered_packets,
        retransmitted_packets)."""
        n_pkts = -(-nbytes // self.packet_bytes)
        retrans = int(rng.binomial(n_pkts, self.packet_loss))
        return n_pkts, retrans


@dataclass(frozen=True)
class ContactSchedule:
    """Ground-station visibility: a LEO satellite sees a given station
    for ~8 minutes, a handful of passes per day."""
    link: LinkModel = LinkModel()
    contact_duration_s: float = 480.0
    contacts_per_day: int = 6
    seed: int = 0

    def windows(self, horizon_s: float) -> List[Tuple[float, float]]:
        """Deterministic pseudo-random contact windows over a horizon.

        Dense schedules (``contact_duration_s`` >= the inter-contact
        period) have no slack to jitter within: the slack term clamps
        to zero and each pass starts no earlier than the previous pass
        ends, so windows never silently overlap.  Sparse schedules draw
        the identical jitter stream they always did.
        """
        rng = np.random.default_rng(self.seed)
        period = SECONDS_PER_DAY / self.contacts_per_day
        slack = max(period - self.contact_duration_s, 0.0)
        out = []
        t, prev_end = 0.0, 0.0
        while t < horizon_s:
            start = max(t + float(rng.uniform(0.2, 0.8)) * slack, prev_end)
            if start >= horizon_s:     # clamped starts can outrun the
                break                  # horizon once passes back up
            out.append((start, min(start + self.contact_duration_s,
                                   horizon_s)))
            prev_end = start + self.contact_duration_s
            t += period
        return out

    def in_contact(self, t: float, horizon_s: float = SECONDS_PER_DAY) -> bool:
        return any(a <= t < b for a, b in self.windows(horizon_s))

    def next_window(self, t: float, horizon_s: float = SECONDS_PER_DAY):
        for a, b in self.windows(horizon_s):
            if b > t:
                return (max(a, t), b)
        return None

    def downlink_capacity_bytes(self, horizon_s: float) -> float:
        """Total bytes deliverable over the horizon."""
        total_s = sum(max(b - a, 0.0) for a, b in self.windows(horizon_s))
        return total_s * self.link.downlink_mbps * 1e6 / 8.0 * (
            1.0 - self.link.packet_loss)

    def step_windows(self, s_per_step: float,
                     horizon_s: float) -> List[Tuple[int, int]]:
        """Contact windows quantized to engine decode-step ticks
        [start_step, end_step) — the clock base the preemptive scheduler
        runs on (``serving.scheduler``).  A window shorter than one step
        still claims the tick it lands in: the downlink pass always
        preempts at least one decode step."""
        out = []
        for a, b in self.windows(horizon_s):
            if b <= a:
                continue         # start past the horizon, end clamped to
                #                  it: zero-capacity, not a real pass
            lo = int(a // s_per_step)
            hi = max(int(-(-b // s_per_step)), lo + 1)
            out.append((lo, hi))
        return out

    # -- constellation extension -------------------------------------------
    def for_pair(self, satellite: int, station: int,
                 contacts_per_day: Optional[int] = None,
                 contact_duration_s: Optional[float] = None,
                 ) -> "ContactSchedule":
        """The (satellite, station) member of a constellation's window
        set: same link and pass geometry, an independent deterministic
        jitter stream derived from the base seed.  Different orbital
        planes see a station with different pass rates, so the per-pair
        density/duration may be overridden."""
        return replace(
            self,
            seed=self.seed * 1_000_003 + satellite * 1009 + station,
            contacts_per_day=(self.contacts_per_day if contacts_per_day
                              is None else contacts_per_day),
            contact_duration_s=(self.contact_duration_s if
                                contact_duration_s is None else
                                contact_duration_s))

    def step_window_sets(self, s_per_step: float, horizon_s: float, *,
                         n_satellites: int, n_stations: int,
                         contacts_per_day=None, contact_duration_s=None,
                         ) -> Dict[Tuple[int, int], List[Tuple[int, int]]]:
        """Per-(satellite, station) tick-quantized window sets — the
        visibility input of ``serving.constellation``.  The optional
        ``contacts_per_day`` / ``contact_duration_s`` accept either a
        scalar (uniform constellation) or a length-``n_satellites``
        sequence (asymmetric orbits: a plane with a poor station
        geometry gets fewer/shorter passes)."""
        def pick(v, k, default):
            if v is None:
                return default
            if isinstance(v, (list, tuple)):
                return v[k]
            return v

        return {
            (k, m): self.for_pair(
                k, m,
                contacts_per_day=pick(contacts_per_day, k,
                                      self.contacts_per_day),
                contact_duration_s=pick(contact_duration_s, k,
                                        self.contact_duration_s),
            ).step_windows(s_per_step, horizon_s)
            for k in range(n_satellites) for m in range(n_stations)}


_BACKOFF_CAP_TICKS = 8


class _Frame:
    """One fixed-size slice of a payload on the framed lane."""
    __slots__ = ("nbytes", "data", "crc", "attempts", "eligible_tick",
                 "delivered")

    def __init__(self, nbytes: float, data: bytes):
        self.nbytes = float(nbytes)
        self.data = data                      # synthetic on-the-wire bytes
        self.crc = zlib.crc32(data)           # computed at the SENDER
        self.attempts = 0
        self.eligible_tick = 0                # NACK backoff gate
        self.delivered = False


class _FramedPayload:
    __slots__ = ("item", "nbytes", "frames", "n_delivered", "failed")

    def __init__(self, item, nbytes: float, frames: List[_Frame]):
        self.item = item
        self.nbytes = float(nbytes)
        self.frames = frames
        self.n_delivered = 0
        self.failed = False


class TransmitLane:
    """The downlink half of the overlapped contact pipeline.

    A FIFO of queued payloads drained *incrementally* against a per-tick
    byte budget, so a scheduler can interleave one decode step with one
    tick of transmission instead of holding the compute for a whole
    pass.  A payload larger than one tick's budget carries its partial
    progress across ticks (and across windows — an unfinished head
    simply waits for the next pass).

    ``tick(budget)`` returns the items whose transmission *completed*
    this tick, in FIFO order.  Determinism: same enqueues + same budgets
    (+ same fault plan) => same completion ticks and byte ledger.

    Two modes:

    * **Unframed** (default, ``frame_bytes=None``): the original
      byte-granular lane — a perfect link, partial progress carries at
      float precision.
    * **Framed** (``frame_bytes=N``): each payload is split into fixed
      ``N``-byte frames (last one partial).  Every frame carries real
      synthetic header bytes and a CRC32 computed at the sender; the
      receiver recomputes the CRC on what actually arrived, so a
      bit-flipped frame is *detected*, never silently delivered.  Lost
      and corrupt frames are NACKed and retransmitted with exponential
      per-tick backoff under a bounded per-frame retry budget
      (``max_retries`` attempts); a frame that exhausts its budget fails
      the whole payload, which is surfaced via :meth:`take_failed` for
      the caller to re-enqueue.  An optional
      :class:`repro.core.faults.FaultInjector` decides each frame's
      in-transit fate; without one the framed lane is lossless.

    Framed byte ledger (conserved every tick):
    ``frame_bytes_attempted == bytes_sent + bytes_lost + bytes_corrupt``
    where ``bytes_sent`` keeps its unframed meaning — *goodput*, bytes
    that arrived intact — so callers metering delivered bytes read the
    same counter in both modes.
    """

    def __init__(self, *, frame_bytes: Optional[int] = None,
                 max_retries: int = 8, injector=None):
        if frame_bytes is not None and frame_bytes <= 0:
            raise ValueError("frame_bytes must be positive")
        if injector is not None and frame_bytes is None:
            raise ValueError("a FaultInjector needs a framed lane "
                             "(frame_bytes=...) to act on")
        self.frame_bytes = frame_bytes
        self.max_retries = int(max_retries)
        self.injector = injector
        self._q: deque = deque()   # unframed: [item, rem]; framed: payloads
        self._failed: List[_FramedPayload] = []
        self._next_pid = 0
        self._tick_no = 0
        self.bytes_sent = 0.0             # goodput: intact delivered bytes
        self.n_completed = 0
        self.n_partial_ticks = 0          # ticks ending mid-payload
        # framed-mode ledger
        self.frame_bytes_attempted = 0.0  # every transmission attempt
        self.bytes_lost = 0.0
        self.bytes_corrupt = 0.0
        self.bytes_retransmitted = 0.0    # attempts after the first
        self.n_frames_sent = 0
        self.n_frames_lost = 0
        self.n_retransmits = 0
        self.n_corruptions_detected = 0
        self.n_silent_corruptions = 0     # corrupt frame passing CRC: gated 0
        self.n_payload_failures = 0

    @property
    def framed(self) -> bool:
        return self.frame_bytes is not None

    def enqueue(self, item, nbytes: float) -> None:
        if not self.framed:
            self._q.append([item, float(nbytes)])
            return
        pid = self._next_pid
        self._next_pid += 1
        nbytes = float(nbytes)
        n_frames = max(1, int(-(-nbytes // self.frame_bytes)))
        frames = []
        for seq in range(n_frames):
            sz = min(float(self.frame_bytes), nbytes - seq * self.frame_bytes)
            # real header bytes so the CRC protects something concrete;
            # the payload body is synthetic in this replay
            frames.append(_Frame(sz, struct.pack("<QI", pid, seq)))
        self._q.append(_FramedPayload(item, nbytes, frames))

    def __len__(self) -> int:
        return len(self._q)

    def pending_bytes(self) -> float:
        if not self.framed:
            return sum(rem for _, rem in self._q)
        return sum(fr.nbytes for p in self._q for fr in p.frames
                   if not fr.delivered)

    def pending_items(self) -> List:
        if not self.framed:
            return [item for item, _ in self._q]
        return [p.item for p in self._q]

    def pending_payloads(self) -> List[Tuple[object, float]]:
        """(item, total_bytes) per queued payload — what a checkpoint
        must persist to rebuild the backlog after a reboot (partial ARQ
        progress does not survive a crash; the payload restarts)."""
        if not self.framed:
            return [(item, rem) for item, rem in self._q]
        return [(p.item, p.nbytes) for p in self._q]

    def take_failed(self) -> List[Tuple[object, float]]:
        """(item, total_bytes) of payloads that exhausted their frame
        retry budgets; the caller decides whether to re-enqueue."""
        out = [(p.item, p.nbytes) for p in self._failed]
        self._failed.clear()
        return out

    def clear(self) -> List:
        """Drop the backlog (horizon exhausted); returns the items,
        including payloads parked in the failed list."""
        out = self.pending_items() + [p.item for p in self._failed]
        self._q.clear()
        self._failed.clear()
        return out

    def tick(self, budget_bytes: float) -> List:
        """Transmit up to ``budget_bytes`` off the FIFO head; returns
        the items fully delivered this tick."""
        if self.framed:
            return self._tick_framed(budget_bytes)
        done = []
        remaining = float(budget_bytes)
        while self._q and self._q[0][1] <= remaining:
            item, nbytes = self._q.popleft()
            remaining -= nbytes
            self.bytes_sent += nbytes
            self.n_completed += 1
            done.append(item)
        if self._q and remaining > 0.0:
            self._q[0][1] -= remaining
            self.bytes_sent += remaining
            self.n_partial_ticks += 1
        return done

    def _tick_framed(self, budget_bytes: float) -> List:
        self._tick_no += 1
        remaining = float(budget_bytes)
        attempted_any = False
        for p in self._q:
            if remaining <= 0.0:
                break
            if p.failed:
                continue
            for fr in p.frames:
                if fr.delivered or fr.eligible_tick > self._tick_no:
                    continue
                if fr.nbytes > remaining:
                    remaining = -1.0      # budget quantum exhausted: frames
                    break                 # transmit whole or not at all
                remaining -= fr.nbytes
                attempted_any = True
                self._transmit(p, fr)
                if p.failed:
                    break    # retry budget blown: stop burning link on it
            if remaining < 0.0:
                break
        # payloads are RELEASED in FIFO enqueue order even though frame
        # completions can land out of order under retransmission
        done = []
        while self._q and not self._q[0].failed \
                and self._q[0].n_delivered == len(self._q[0].frames):
            p = self._q.popleft()
            self.n_completed += 1
            done.append(p.item)
        if any(p.failed for p in self._q):
            live = deque()
            for p in self._q:
                (self._failed if p.failed else live).append(p)
            self._q = live
        if attempted_any and self._q and self._q[0].n_delivered > 0:
            self.n_partial_ticks += 1
        return done

    def _transmit(self, p: _FramedPayload, fr: _Frame) -> None:
        fr.attempts += 1
        self.n_frames_sent += 1
        self.frame_bytes_attempted += fr.nbytes
        if fr.attempts > 1:
            self.n_retransmits += 1
            self.bytes_retransmitted += fr.nbytes
        fate = self.injector.frame_fate() if self.injector is not None \
            else "ok"
        if fate == "lost":
            self.bytes_lost += fr.nbytes
            self.n_frames_lost += 1
            self._nack(p, fr)
            return
        rx = self.injector.corrupt_bytes(fr.data) if fate == "corrupt" \
            else fr.data
        if zlib.crc32(rx) == fr.crc:
            if fate == "corrupt":
                self.n_silent_corruptions += 1   # unreachable for CRC32 +
                #                                  single-bit flips; gated 0
            fr.delivered = True
            p.n_delivered += 1
            self.bytes_sent += fr.nbytes
        else:
            self.n_corruptions_detected += 1
            self.bytes_corrupt += fr.nbytes
            self._nack(p, fr)

    def _nack(self, p: _FramedPayload, fr: _Frame) -> None:
        if fr.attempts >= self.max_retries:
            p.failed = True
            self.n_payload_failures += 1
        else:
            backoff = min(2 ** (fr.attempts - 1), _BACKOFF_CAP_TICKS)
            fr.eligible_tick = self._tick_no + backoff

    # -- checkpoint bookkeeping ---------------------------------------------
    # A reboot rebuilds the lane from pending_payloads(); the counters
    # roll back with the rest of the serving state so injected-vs-
    # detected stays exact across the rewind (see core.faults).
    _STATE_KEYS = ("bytes_sent", "n_completed", "n_partial_ticks",
                   "frame_bytes_attempted", "bytes_lost", "bytes_corrupt",
                   "bytes_retransmitted", "n_frames_sent", "n_frames_lost",
                   "n_retransmits", "n_corruptions_detected",
                   "n_silent_corruptions", "n_payload_failures")

    def state(self) -> dict:
        return {k: getattr(self, k) for k in self._STATE_KEYS}

    def load_state(self, d: dict) -> None:
        for k in self._STATE_KEYS:
            setattr(self, k, d[k])


def payload_bytes_result(n_items: int, classes: int = 1) -> int:
    """Compact inference result: class id + confidence + bbox-ish tuple
    per item (16 bytes, generous)."""
    return 16 * n_items * max(classes, 1)


def payload_bytes_raw(n_items: int, item_shape, dtype_bytes: int = 1) -> int:
    n = 1
    for d in item_shape:
        n *= d
    return n_items * n * dtype_bytes


def payload_bytes_draft(n_draft: int) -> int:
    """Speculative escalation payload: the satellite tier's draft token
    ids (4 bytes each) plus a small header (request reference + lengths
    — the ground tier already holds the prompt from the uplink relay,
    so nothing else crosses the link).  Compare ``payload_bytes_raw``,
    which ships the whole prompt payload for a from-scratch re-decode."""
    return 4 * n_draft + 16
