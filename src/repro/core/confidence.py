"""Confidence metrics over posterior logits.

The paper gates on detector confidence; our tiers are classifiers/LMs,
so the gate consumes (B, V) logits.  On TPU the fused Pallas
``conf_gate`` kernel computes all metrics in one HBM pass (vocabs up to
152k make the naive 3-pass softmax->max->entropy memory-bound); the jnp
path is used inside jit'd training/eval code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

METRICS = ("max_prob", "entropy", "margin")


def confidence_metrics(logits: jax.Array, *, use_kernel: bool = False) -> dict:
    """logits: (..., V) -> dict of (...,)-shaped metrics + argmax."""
    if use_kernel:
        from repro.kernels import ops
        flat = logits.reshape(-1, logits.shape[-1])
        out = ops.confidence_gate(flat)
        return {k: v.reshape(logits.shape[:-1]) for k, v in out.items()}
    from repro.kernels.ref import confidence_gate_ref
    flat = logits.reshape(-1, logits.shape[-1])
    out = confidence_gate_ref(flat)
    return {k: v.reshape(logits.shape[:-1]) for k, v in out.items()}


def normalized_entropy_confidence(entropy: jax.Array, vocab: int) -> jax.Array:
    """Map entropy to a [0,1] confidence (1 = fully confident)."""
    return 1.0 - entropy / jnp.log(vocab)


def score(metrics: dict, metric: str, vocab: int) -> jax.Array:
    """A single scalar confidence in [0, 1] per item."""
    if metric == "max_prob":
        return metrics["max_prob"]
    if metric == "margin":
        return metrics["margin"]
    if metric == "entropy":
        return normalized_entropy_confidence(metrics["entropy"], vocab)
    raise ValueError(metric)
