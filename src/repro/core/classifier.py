"""Tile classifiers for the case study — the YOLOv3-tiny / YOLOv3
analogue pair (DESIGN.md §2): an onboard (small) and a ground (large)
classifier over EO tiles, trained with the framework's own AdamW.

Patch-embedding + mean-pooled MLP trunk; capacity (width/depth) is the
only difference between tiers, mirroring the paper's tiny-vs-full
detector split.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.training import optim

F32 = jnp.float32


@dataclass(frozen=True)
class ClassifierConfig:
    tile: int = 32
    patch: int = 8
    d_model: int = 48
    n_layers: int = 2
    n_classes: int = 8
    seed: int = 0


ONBOARD = ClassifierConfig(d_model=24, n_layers=1)     # Pi-class budget
GROUND = ClassifierConfig(d_model=96, n_layers=4)      # ground cluster


def init_classifier(cfg: ClassifierConfig):
    key = jax.random.PRNGKey(cfg.seed)
    ks = jax.random.split(key, cfg.n_layers * 2 + 2)
    pdim = cfg.patch * cfg.patch * 3
    p = {"embed": L.dense_init(ks[0], (pdim, cfg.d_model), F32)}
    for i in range(cfg.n_layers):
        p[f"mlp{i}"] = L.init_swiglu(ks[i + 1], cfg.d_model,
                                     cfg.d_model * 4, F32)
        p[f"ln{i}"] = L.init_rmsnorm(cfg.d_model, F32)
    p["head"] = L.dense_init(ks[-1], (cfg.d_model, cfg.n_classes), F32)
    return p


def apply_classifier(params, cfg: ClassifierConfig, tiles):
    """tiles: (B, t, t, 3) -> logits (B, n_classes)."""
    B, t, _, C = tiles.shape
    pp = cfg.patch
    n = t // pp
    x = tiles.reshape(B, n, pp, n, pp, C).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(B, n * n, pp * pp * C).astype(F32)
    h = x @ params["embed"]                     # (B, P, d)
    for i in range(cfg.n_layers):
        h = h + L.swiglu(params[f"mlp{i}"], L.rmsnorm(params[f"ln{i}"], h))
    pooled = h.mean(axis=1)
    return pooled @ params["head"]


def train_classifier(cfg: ClassifierConfig, tiles, labels, *,
                     steps: int = 300, batch: int = 64, lr: float = 3e-3,
                     seed: int = 0):
    """Train on labeled (non-cloudy) tiles.  Returns trained params."""
    keep = labels >= 0
    X = jnp.asarray(tiles[keep])
    Y = jnp.asarray(labels[keep])
    params = init_classifier(cfg)
    ocfg = optim.OptimConfig(lr=lr, warmup_steps=20, total_steps=steps,
                             weight_decay=0.01)
    state = optim.adamw_init(params, ocfg)

    @jax.jit
    def step_fn(params, state, xb, yb):
        def lf(p):
            logits = apply_classifier(p, cfg, xb)
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(lp, yb[:, None], 1))
        loss, grads = jax.value_and_grad(lf)(params)
        params, state, _ = optim.adamw_update(params, grads, state, ocfg)
        return params, state, loss

    rng = np.random.default_rng(seed)
    n = X.shape[0]
    loss = None
    for s in range(steps):
        idx = rng.integers(0, n, size=min(batch, n))
        params, state, loss = step_fn(params, state, X[idx], Y[idx])
    return params, float(loss)


def accuracy(params, cfg: ClassifierConfig, tiles, labels) -> float:
    keep = labels >= 0
    logits = apply_classifier(params, cfg, jnp.asarray(tiles[keep]))
    return float(jnp.mean((jnp.argmax(logits, -1) ==
                           jnp.asarray(labels[keep])).astype(F32)))
