"""Deterministic fault injection for the space-ground replay.

The paper's premise is that the downlink is scarce AND unreliable —
§II cites a mission that lost 80% of its packets — and a cloud-native
satellite must additionally survive payload reboots.  This module
turns those failure modes into a seeded, replayable plan:

  * per-frame packet erasure and bit-flip corruption on the transmit
    lane (``core.link.TransmitLane`` in framed mode draws one fate per
    frame transmission);
  * early-LOS truncation of contact windows (a pass ends before the
    predicted geometry says it should);
  * spill-store record corruption (a bit flips in a host-side KV spill
    — ``serving.paging.DeltaSpillStore`` must DETECT it, never graft
    it);
  * a scheduled satellite crash at engine tick ``t`` (the serving
    state must restore from its last checkpoint and resume
    token-exactly).

Everything is driven by ONE ``numpy`` PRNG seeded from the plan, so a
replay under the same plan injects the identical fault sequence.  The
injector's counters are the ground truth the benchmark gates against
(every injected corruption must be detected downstream); they round
trip through ``state()``/``load_state()`` so a crash-rollback restores
the bookkeeping to the checkpoint's instant consistently with the
subsystems it audits.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class FaultPlan:
    """A seeded description of what goes wrong, and when."""
    seed: int = 0
    # -- transmit lane: one fate drawn per frame transmission ---------------
    frame_loss_rate: float = 0.0       # P(frame erased in transit)
    frame_corrupt_rate: float = 0.0    # P(frame arrives bit-flipped)
    # -- contact windows ----------------------------------------------------
    truncate_every: int = 0            # every k-th pass ends early (0: never)
    truncate_frac: float = 0.5         # fraction of the pass that survives
    # -- spill store --------------------------------------------------------
    spill_corrupt_every: int = 0       # every k-th store merge lands with a
    #                                    flipped bit in its host record
    # -- crash --------------------------------------------------------------
    crash_at_tick: Optional[int] = None   # satellite reboot at this tick

    def __post_init__(self):
        if not 0.0 <= self.frame_loss_rate + self.frame_corrupt_rate <= 1.0:
            raise ValueError("frame_loss_rate + frame_corrupt_rate must lie "
                             "in [0, 1]")
        if not 0.0 < self.truncate_frac <= 1.0:
            raise ValueError("truncate_frac must lie in (0, 1]")


class FaultInjector:
    """Draws the plan's faults, deterministically, and counts them.

    The counters are the benchmark's injected-fault ground truth:
    ``n_frame_corruptions`` must equal the lane's CRC-failure count and
    ``n_spill_corruptions`` the store's checksum-failure count — 100%
    detection, zero silent acceptance.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._rng = np.random.default_rng(plan.seed)
        self.n_frames_lost = 0
        self.n_frame_corruptions = 0
        self.n_spill_corruptions = 0
        self.n_windows_truncated = 0
        self.n_crashes = 0
        self._merge_count = 0
        self._crashed = False

    # -- transmit lane -------------------------------------------------------
    def frame_fate(self) -> str:
        """One of "ok" | "lost" | "corrupt" for a frame transmission."""
        p = self.plan
        if p.frame_loss_rate == 0.0 and p.frame_corrupt_rate == 0.0:
            return "ok"
        u = float(self._rng.random())
        if u < p.frame_loss_rate:
            self.n_frames_lost += 1
            return "lost"
        if u < p.frame_loss_rate + p.frame_corrupt_rate:
            self.n_frame_corruptions += 1
            return "corrupt"
        return "ok"

    def corrupt_bytes(self, data: bytes) -> bytes:
        """Flip one (seeded) bit — a CRC32 always catches a single-bit
        error, so detection downstream is a property of the code, not a
        simulation flag."""
        buf = bytearray(data)
        bit = int(self._rng.integers(0, len(buf) * 8))
        buf[bit // 8] ^= 1 << (bit % 8)
        return bytes(buf)

    def corrupt_offset(self, nbytes: int) -> int:
        """Seeded byte offset for an in-place record flip."""
        return int(self._rng.integers(0, max(nbytes, 1)))

    # -- contact windows -----------------------------------------------------
    def truncate_step_windows(self, windows: List[Tuple[int, int]]
                              ) -> List[Tuple[int, int]]:
        """Apply early-LOS truncation: every ``truncate_every``-th pass
        keeps only the leading ``truncate_frac`` of its ticks (at least
        one — a pass that opened did transmit something)."""
        k = self.plan.truncate_every
        if k <= 0:
            return list(windows)
        out = []
        for i, (lo, hi) in enumerate(windows):
            if (i + 1) % k == 0:
                kept = max(1, int((hi - lo) * self.plan.truncate_frac))
                if lo + kept < hi:
                    self.n_windows_truncated += 1
                hi = min(hi, lo + kept)
            out.append((lo, hi))
        return out

    # -- spill store ---------------------------------------------------------
    def spill_corruption_due(self) -> bool:
        """Called once per store merge; True when this record should be
        corrupted in place (the caller flips the byte and the injector
        counts the injection)."""
        k = self.plan.spill_corrupt_every
        if k <= 0:
            return False
        self._merge_count += 1
        if self._merge_count % k == 0:
            self.n_spill_corruptions += 1
            return True
        return False

    # -- crash ---------------------------------------------------------------
    def crash_due(self, tick: int) -> bool:
        return (self.plan.crash_at_tick is not None and not self._crashed
                and tick >= self.plan.crash_at_tick)

    def note_crash(self) -> None:
        self._crashed = True
        self.n_crashes += 1

    # -- checkpoint bookkeeping ---------------------------------------------
    # A crash rolls the serving state back to its last checkpoint; the
    # injector's counters (and PRNG) roll back WITH it so injected-vs-
    # detected stays an exact invariant across the rewind.  The crash
    # flags themselves never roll back — a crash that fired stays fired.
    def state(self) -> dict:
        s = self._rng.bit_generator.state
        return {
            "n_frames_lost": self.n_frames_lost,
            "n_frame_corruptions": self.n_frame_corruptions,
            "n_spill_corruptions": self.n_spill_corruptions,
            "n_windows_truncated": self.n_windows_truncated,
            "merge_count": self._merge_count,
            # PCG64 state words exceed 64 bits — msgpack only carries
            # uint64, so they travel as decimal strings
            "rng": {"bit_generator": s["bit_generator"],
                    "state": str(s["state"]["state"]),
                    "inc": str(s["state"]["inc"]),
                    "has_uint32": int(s["has_uint32"]),
                    "uinteger": int(s["uinteger"])},
        }

    def load_state(self, d: dict) -> None:
        self.n_frames_lost = int(d["n_frames_lost"])
        self.n_frame_corruptions = int(d["n_frame_corruptions"])
        self.n_spill_corruptions = int(d["n_spill_corruptions"])
        self.n_windows_truncated = int(d["n_windows_truncated"])
        self._merge_count = int(d["merge_count"])
        r = d["rng"]
        if r["bit_generator"] != self._rng.bit_generator.state[
                "bit_generator"]:
            raise ValueError(
                f"fault-plan RNG is {r['bit_generator']!r}, expected "
                f"{self._rng.bit_generator.state['bit_generator']!r}")
        self._rng.bit_generator.state = {
            "bit_generator": r["bit_generator"],
            "state": {"state": int(r["state"]), "inc": int(r["inc"])},
            "has_uint32": int(r["has_uint32"]),
            "uinteger": int(r["uinteger"]),
        }

    @property
    def n_corruptions_injected(self) -> int:
        """Total corruptions across both injection surfaces — the
        benchmark's zero-silent-acceptance denominator."""
        return self.n_frame_corruptions + self.n_spill_corruptions
