"""Cloud-native orchestration layer (KubeEdge/Sedna analogue, DESIGN.md
§2): node registry, application deployer, lossy space-ground message
bus, offline-autonomy metadata store."""
from repro.orchestration.registry import NodeSpec, Registry      # noqa
from repro.orchestration.bus import MessageBus, Message          # noqa
from repro.orchestration.deployer import AppManifest, Deployer   # noqa
from repro.orchestration.autonomy import MetadataStore           # noqa
