"""Node registry — CloudCore/EdgeCore analogue.

Nodes are satellites (edge) or ground stations / cloud (core).  The
registry tracks liveness based on contact windows: a satellite is
"reachable" only during a ground-station pass; it keeps running
autonomously while unreachable (the paper's "offline autonomous")."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.link import ContactSchedule, LinkModel


@dataclass
class NodeSpec:
    name: str
    kind: str                      # "satellite" | "ground"
    compute_w: float = 8.78        # Table 3: Pi-class payload power
    memory_gb: float = 4.0
    link: Optional[LinkModel] = None
    contacts: Optional[ContactSchedule] = None
    labels: Dict[str, str] = field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in ("satellite", "ground"):
            raise ValueError(self.kind)
        if self.kind == "satellite" and self.contacts is None:
            self.contacts = ContactSchedule(link=self.link or LinkModel())


class Registry:
    def __init__(self):
        self._nodes: Dict[str, NodeSpec] = {}

    def register(self, node: NodeSpec) -> None:
        if node.name in self._nodes:
            raise KeyError(f"duplicate node {node.name!r}")
        self._nodes[node.name] = node

    def get(self, name: str) -> NodeSpec:
        return self._nodes[name]

    def nodes(self, kind: Optional[str] = None):
        return [n for n in self._nodes.values()
                if kind is None or n.kind == kind]

    def reachable(self, name: str, t: float) -> bool:
        n = self._nodes[name]
        if n.kind == "ground":
            return True
        return n.contacts.in_contact(t)
