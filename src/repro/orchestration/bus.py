"""Space-ground message bus.

Messages between a satellite and the ground are deliverable only during
contact windows and pay the link-rate + loss cost; ground<->ground is
instantaneous.  The bus is a discrete-event queue driven by an explicit
clock (deterministic; tests advance time)."""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.orchestration.registry import Registry

_seq = itertools.count()


@dataclass(order=True)
class Message:
    deliver_t: float
    seq: int = field(compare=True)
    src: str = field(compare=False, default="")
    dst: str = field(compare=False, default="")
    topic: str = field(compare=False, default="")
    payload: Any = field(compare=False, default=None)
    nbytes: int = field(compare=False, default=0)


class MessageBus:
    def __init__(self, registry: Registry):
        self.registry = registry
        self._pending: List[Message] = []
        self._subs: Dict[Tuple[str, str], List[Callable]] = {}
        self.delivered_bytes: float = 0.0
        self.clock: float = 0.0

    def subscribe(self, node: str, topic: str, fn: Callable) -> None:
        self._subs.setdefault((node, topic), []).append(fn)

    def _deliver_time(self, src: str, dst: str, nbytes: int,
                      t: float) -> Optional[float]:
        s, d = self.registry.get(src), self.registry.get(dst)
        sat = s if s.kind == "satellite" else (
            d if d.kind == "satellite" else None)
        if sat is None:
            return t                                   # ground <-> ground
        win = sat.contacts.next_window(t, horizon_s=86_400.0 * 2)
        if win is None:
            return None
        start = max(win[0], t)
        down = s.kind == "satellite"
        link = sat.contacts.link
        tx = (link.downlink_time_s(nbytes) if down
              else link.uplink_time_s(nbytes))
        if start + tx > win[1]:                        # spills past window
            nxt = sat.contacts.next_window(win[1] + 1.0)
            if nxt is None:
                return None
            start = nxt[0]
        return start + tx

    def send(self, src: str, dst: str, topic: str, payload: Any,
             nbytes: int, t: Optional[float] = None) -> Optional[float]:
        """Queue a message; returns its delivery time (None = undeliverable)."""
        t = self.clock if t is None else t
        dt = self._deliver_time(src, dst, nbytes, t)
        if dt is None:
            return None
        heapq.heappush(self._pending,
                       Message(dt, next(_seq), src, dst, topic, payload,
                               nbytes))
        return dt

    def advance(self, until: float) -> int:
        """Advance the clock, delivering due messages.  Returns count."""
        n = 0
        while self._pending and self._pending[0].deliver_t <= until:
            msg = heapq.heappop(self._pending)
            self.delivered_bytes += msg.nbytes
            for fn in self._subs.get((msg.dst, msg.topic), []):
                fn(msg)
            n += 1
        self.clock = max(self.clock, until)
        return n
