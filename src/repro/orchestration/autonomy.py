"""Offline-autonomy metadata store (KubeEdge MetaManager analogue):
desired/actual state survives node restarts; satellites manage and
restore applications from local metadata while disconnected."""
from __future__ import annotations

import copy
import json
import os
from typing import Any, Dict, Optional


class MetadataStore:
    def __init__(self, path: Optional[str] = None):
        self._path = path
        self._desired: Dict[str, dict] = {}
        self._actual: Dict[str, str] = {}
        if path and os.path.exists(path):
            with open(path) as f:
                obj = json.load(f)
            self._desired = obj.get("desired", {})
            self._actual = obj.get("actual", {})

    def _flush(self) -> None:
        if self._path:
            tmp = self._path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"desired": self._desired, "actual": self._actual},
                          f)
            os.replace(tmp, self._path)

    def record_desired(self, name: str, spec: dict) -> None:
        self._desired[name] = copy.deepcopy(spec)
        self._flush()

    def remove_desired(self, name: str) -> None:
        self._desired.pop(name, None)
        self._flush()

    def record_actual(self, name: str, state: str) -> None:
        self._actual[name] = state
        self._flush()

    def desired(self) -> Dict[str, dict]:
        return copy.deepcopy(self._desired)

    def actual(self, name: str) -> Optional[str]:
        return self._actual.get(name)
