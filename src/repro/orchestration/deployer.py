"""Application deployer — the Sedna GlobalManager analogue.

An AppManifest names a model config + tier placement; the Deployer
instantiates workers (serving engines or classifier tiers) on registered
nodes and keeps desired state in the MetadataStore so satellites can
restore workloads after an offline period (paper: "offline autonomous").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from repro.orchestration.autonomy import MetadataStore
from repro.orchestration.registry import Registry


@dataclass(frozen=True)
class AppManifest:
    name: str
    node: str
    factory: Callable[[], Any]          # builds the worker (engine/tier)
    labels: Dict[str, str] = field(default_factory=dict)


class Deployer:
    def __init__(self, registry: Registry,
                 store: Optional[MetadataStore] = None):
        self.registry = registry
        self.store = store or MetadataStore()
        self._workers: Dict[str, Any] = {}

    def apply(self, manifest: AppManifest) -> Any:
        """Deploy (or redeploy) an app; records desired state first, so a
        crash between record and start is recoverable."""
        self.registry.get(manifest.node)        # must exist
        self.store.record_desired(manifest.name, {
            "node": manifest.node, "labels": dict(manifest.labels)})
        worker = manifest.factory()
        self._workers[manifest.name] = worker
        self.store.record_actual(manifest.name, "running")
        return worker

    def delete(self, name: str) -> None:
        self._workers.pop(name, None)
        self.store.record_actual(name, "deleted")
        self.store.remove_desired(name)

    def worker(self, name: str) -> Any:
        return self._workers[name]

    def restore(self, factories: Dict[str, Callable[[], Any]]) -> int:
        """Offline-autonomy restart: rebuild every desired app that is not
        running (MetaManager restore path).  Returns number restored."""
        n = 0
        for name, spec in self.store.desired().items():
            if self.store.actual(name) != "running":
                self._workers[name] = factories[name]()
                self.store.record_actual(name, "running")
                n += 1
        return n
