"""Serving throughput: fixed-slot vs continuous batching.

Replays ONE Poisson arrival trace (mixed prompt lengths, heterogeneous
decode budgets) through both engines and reports useful tokens per
second.  The fixed-slot engine pads every request to the longest prompt
in its batch and decodes the batch's max ``max_new`` for every row —
slots holding finished sequences burn steps until the batch drains.
The continuous engine evicts finished sequences and admits queued
arrivals mid-flight, so nearly every slot-step emits a useful token.

Writes the headline numbers to ``BENCH_serving.json`` in the repo root.

    PYTHONPATH=src python -m benchmarks.serving_throughput
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

N_REQUESTS = 24
N_SLOTS = 4
MAX_SEQ = 64
ARRIVAL_RATE = 0.5          # mean arrivals per decode step
PROMPT_LENS = (4, 16)
MAX_NEW = (2, 24)


def _make_engine_inputs():
    from repro.config import get_reduced_config
    from repro.serving.batching import poisson_trace

    cfg = get_reduced_config("smollm-360m")
    trace = poisson_trace(N_REQUESTS, rate=ARRIVAL_RATE,
                          prompt_lens=PROMPT_LENS, max_new=MAX_NEW,
                          vocab_size=cfg.vocab_size, seed=7)
    return cfg, trace


def _serve_fixed(cfg, params, trace):
    """Fixed-slot baseline: the seed ``RequestQueue.next_batch``
    discipline (FIFO, pad to the batch's longest prompt) with each batch
    decoded for its max ``max_new``.  The clock (in decode steps) only
    advances while the batch drains, so a new batch forms from whatever
    has arrived by then.  Returns (useful_tokens, wall_seconds)."""
    from repro.serving.batching import RequestQueue
    from repro.serving.engine import ServingEngine

    eng = ServingEngine(cfg, params, max_seq=MAX_SEQ)
    queue = RequestQueue(max_batch=N_SLOTS)
    pending = sorted(trace, key=lambda r: r.arrival_t)
    clock, useful = 0.0, 0
    t0 = time.perf_counter()
    while pending or len(queue):
        while pending and pending[0].arrival_t <= clock:
            queue.submit(pending.pop(0))
        batch = queue.next_batch()
        if batch is None:
            clock += 1.0                       # idle tick
            continue
        steps = max(r.max_new for r in batch.requests)
        eng.generate(batch.tokens, max_new=steps)
        useful += sum(r.max_new for r in batch.requests)
        clock += steps
    return useful, time.perf_counter() - t0


def _serve_continuous(cfg, params, trace):
    from repro.serving.engine import ContinuousEngine

    eng = ContinuousEngine(cfg, params, n_slots=N_SLOTS, max_seq=MAX_SEQ)
    t0 = time.perf_counter()
    results = eng.run(list(trace))
    wall = time.perf_counter() - t0
    useful = sum(len(r.tokens) for r in results.values())
    return useful, wall


def run():
    import jax
    from repro.models import transformer as T

    cfg, trace = _make_engine_inputs()
    params = T.init_params(jax.random.PRNGKey(0), cfg, max_seq=MAX_SEQ)

    rows = []
    out = {}
    for name, serve in (("fixed_slot", _serve_fixed),
                        ("continuous", _serve_continuous)):
        serve(cfg, params, trace)              # warmup: populate jit caches
        tokens, wall = serve(cfg, params, trace)
        tps = tokens / wall
        out[name] = {"useful_tokens": tokens, "wall_s": round(wall, 4),
                     "tokens_per_s": round(tps, 2)}
        rows.append((f"serving_{name}", wall * 1e6 / max(tokens, 1),
                     {"tokens_per_s": round(tps, 2)}))

    out["speedup"] = round(out["continuous"]["tokens_per_s"]
                           / out["fixed_slot"]["tokens_per_s"], 3)
    out["trace"] = {"n_requests": N_REQUESTS, "n_slots": N_SLOTS,
                    "arrival_rate": ARRIVAL_RATE,
                    "prompt_lens": list(PROMPT_LENS),
                    "max_new": list(MAX_NEW)}
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_serving.json"), "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    rows.append(("serving_speedup", 0.0, {"speedup": out["speedup"]}))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{json.dumps(derived, sort_keys=True)}")
