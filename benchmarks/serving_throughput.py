"""Serving throughput: fixed-slot vs continuous batching (paged KV),
plus the contact-window preemption replay.

Replays ONE Poisson arrival trace (mixed prompt lengths, heterogeneous
decode budgets) through three configurations and reports useful tokens
per second plus KV-cache memory:

  * ``fixed_slot`` — the seed baseline: every request padded to the
    longest prompt in its batch, the batch decoded to its max max_new.
  * ``continuous`` — continuous batching over the PAGED KV layout (the
    default): a global page pool + per-sequence block tables, so cache
    memory is ``pool_pages * page_size`` positions instead of
    ``n_slots * max_seq``.
  * ``continuous_contiguous`` — continuous batching over the contiguous
    per-slot layout (memory baseline the paged gate compares against).

The paged run must stay token-exact with the contiguous run, hold the
>= 1.5x fixed-slot speedup, and use strictly less KV-cache memory —
all three are CI-gated on ``BENCH_serving.json``.

The CONTACT-WINDOW replay then reruns the same trace under a periodic
downlink schedule (every ``CW_PERIOD`` decode ticks the compute is
yielded for ``CW_DURATION`` ticks — the paper's ground-station pass):

  * ``preemptive`` — ``serving.scheduler.PreemptiveScheduler`` spills
    every in-flight sequence at window open and resumes it token-exactly
    after (reports preemption counts, resume latency, goodput);
  * ``restart`` — the no-preemption baseline: in-flight sequences are
    ABORTED at window open and re-decoded from scratch afterwards.

CI gates: the preemptive replay's tokens equal the uninterrupted run's
for every request, its goodput (useful tokens per clock tick) is >= the
restart baseline's, and the page pool fully drains (no leak).

The OVERLAP replay (``contact_window.overlap``) then reruns the trace
under a denser window schedule twice:

  * ``stop_the_world`` — PR 3 behavior: every pass preempts all decode
    for its whole duration;
  * ``overlapped`` — the contact pipeline: decode continues through the
    pass; only the transmit lane's staging reserve
    (``OV_RESERVE_PAGES`` held via ``hold_pages``) can spill sequences,
    and re-preempted sequences ship only KV-delta pages.

CI gates: overlapped goodput >= stop-the-world goodput on the SAME
schedule, delta spills observed with delta bytes < full-spill bytes,
both replays token-exact with the uninterrupted run, pools drained.

The CHUNKED-PREFILL replay (``chunked_prefill``) serves a heavy-tail
prompt mix (mostly short prompts, a fat tail near max_seq) through the
unified token-budget step twice: budgeted (``PREFILL_BUDGET`` prompt
tokens per tick) vs unbounded (each prompt lands as one chunk — the
monolithic comparator).  Every tick is wall-timed; the section reports
``tick_latency_p50/p99`` and TTFT.  CI gates: the runs are token-exact,
the chunked run's p99 tick latency is STRICTLY below the monolithic
run's on the same trace, per-tick prefill tokens never exceed the
budget, and both pools drain.

The SHARED-PREFIX replay (``shared_prefix``) serves a trace where many
requests repeat a handful of long system headers (the paper's
millions-of-users-per-system-prompt shape) twice through the paged
engine: with ``prefix_cache=True`` (refcounted page sharing +
copy-on-write) and without.  CI gates (GATE_VERSION 4): the shared run
is token-exact with the unshared run, its peak KV pool bytes AND its
total prefill tokens are STRICTLY below the unshared run's, and the
pool/refcounts fully drain once the prefix index is cleared.

The FAULT replay (``fault_replay``) reruns a contended space-ground
trace under an adversarial ``core.faults.FaultPlan`` — per-frame
downlink loss AND bit-flip corruption, early-LOS window truncation,
periodic spill-record corruption, and one scheduled satellite crash
mid-run — against the fault-free replay of the same trace.  CI gates
(GATE_VERSION 5): every final token stream is IDENTICAL to the
fault-free run's (faults cost time and bytes, never answers); every
injected corruption is detected (``n_corruptions_detected ==
n_corruptions_injected``, zero silent acceptances); retransmitted and
lost bytes are metered in the ledger; the framed lane's byte ledger
conserves (attempted == delivered + lost + corrupt); goodput efficiency
is bounded below by the injected loss; the crash is survived via
checkpoint/restore (``n_reboots == 1``) with pools and spill store
drained after.  ``--chaos SEED...`` sweeps FaultPlan seeds and asserts
the same invariants per seed (the CI chaos step).

The SPECULATIVE section (``speculative``) exercises draft–verify
decoding in the unified step twice.  The VERIFY micro-bench serves one
trace through the continuous engine plain, then again with each request
carrying its own plain-run output as a draft stream (perfect
acceptance), so every accepted token rides a chunked verify pass
instead of a decode dispatch.  The CASCADE replay reruns a space-ground
trace whose prompts dwarf the answers twice — raw-prompt escalation vs
draft-id escalation (``payload_bytes_draft``) with ground-side batched
verification.  CI gates (GATE_VERSION 6): both speculative replays are
token-exact with their plain comparators, accepted-token throughput is
>= plain decode's tokens/s in fewer engine ticks, drafts are actually
verified (passes > 0, accepted == drafted under self-drafts), the
draft escalation ships STRICTLY fewer bytes per escalation than the
raw path on the same trace, the ground tier answers escalations in
strictly fewer ticks, and all pools drain.

The CONSTELLATION section (``constellation``) replays one trace — all
of it uplinked through a window-poor satellite — across K=3 satellites
and 2 ground stations twice: the ``ContactPlanner``'s priority-to-value
pass assignment with token-exact inter-satellite handover
(``serving.constellation``) vs the K-independent-pairs comparator
(static home stations, no coordination) on the SAME window sets and
energy model.  CI gates (GATE_VERSION 7): the pooled replay's goodput
is >= the independent pairs' at equal energy/byte budget (both within
the per-satellite bus cap, no extra downlink payload bytes), handovers
actually happened, every answer is token-exact with a solo replay of
the same requests, and every pool, spill store and lane drains.
``--chaos-constellation SEED...`` reruns the pooled replay under a
lossy/corrupting fault plan per seed (the CI chaos step's
constellation lane).

The SHARDED section (``sharded``) replays one trace through the paged
continuous engine twice — single-device vs ``ContinuousEngine(mesh=
make_serving_mesh())``, a tensor-parallel mesh over EVERY visible
device (attention heads + per-device KV page pools sharded on the
``model`` axis, all-gather only at the logits) — plus a MoE replay
whose expert dispatch is expert-parallel over the same axis.  Configs
are fp32 so cross-device reduction order cannot perturb greedy argmax.
On the default 1-device CI lane the mesh is the trivial ``(1, 1)`` and
the section degenerates to an A/A parity check; the ``sharded-smoke``
CI job reruns it 4-way via ``--sharded`` (which forces
``--xla_force_host_platform_device_count=4`` before JAX initializes)
and asserts the 4-shard invariants inline.  CI gates (GATE_VERSION 8):
both replays token-exact with their single-device comparators,
``kv_bytes_per_device * n_kv_shards == kv_cache_bytes`` (page pools
shard only head/latent axes, never page axes, so the per-device ledger
IS the global ledger: ``peak_pages_in_use_per_device ==
peak_pages_in_use``), sharded tokens/s >= ``SHARDED_MIN_RATIO`` x the
single-device run's at equal batch, pools drained, and the MoE run's
``experts_per_device * n_expert_shards == n_experts`` (per-device
dispatch really metered).

The gates live in ``scripts/check_bench.py`` (run it locally after the
benchmark: ``python scripts/check_bench.py BENCH_serving.json``).

    PYTHONPATH=src python -m benchmarks.serving_throughput
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

N_REQUESTS = 24
N_SLOTS = 4
MAX_SEQ = 64
ARRIVAL_RATE = 0.5          # mean arrivals per decode step
PROMPT_LENS = (4, 16)
MAX_NEW = (2, 24)
PAGE_SIZE = 16
CW_PERIOD = 40              # decode ticks between window opens
CW_DURATION = 8             # ticks per window (gap > max max_new so the
                            # restart baseline cannot livelock)
CW_MAX_STEPS = 20_000       # replay safety valve
BENCH_VERSION = 8           # bumped when gated keys change (check_bench)

# overlap replay: denser passes (so long sequences straddle several and
# re-preemption exercises the KV-delta format) + a staging reserve that
# actually contends with the decode working set
OV_PERIOD = 16              # decode ticks between overlap-window opens
OV_DURATION = 4             # ticks per overlap window
OV_RESERVE_PAGES = 8        # pages held for the transmit lane per pass
                            # (2/3 of the default 12-page pool: enough
                            # contention that long sequences re-spill
                            # across passes and exercise delta spills)

# chunked-prefill replay: a HEAVY-TAIL prompt mix (mostly short prompts
# with a fat tail of near-max_seq ones) served twice — with the unified
# step's prefill budget bounding every tick, and with the budget
# removed (each prompt lands as ONE chunk: the monolithic comparator).
# The tail is what the gate is about: a monolithic admission stalls the
# whole tick for the prompt length, so its tail tick latency blows up
# while the chunked run's stays near the decode floor.
HT_N_REQUESTS = 16
HT_MAX_SEQ = 512
HT_RATE = 0.35              # arrivals per tick (slower: long decodes)
HT_LIGHT_PROMPTS = (4, 16)
HT_HEAVY_PROMPTS = (360, 480)
HT_HEAVY_EVERY = 4          # every 4th request draws from the heavy tail
HT_MAX_NEW = (4, 16)
PREFILL_BUDGET = 16         # per-tick prompt-token budget (chunked run)

# shared-prefix replay: SP_N_REQUESTS requests drawn over SP_HEADERS
# distinct system headers of SP_HEADER_PAGES full pages each — the
# prefix index can only share FULL prompt pages, so headers are sized
# in pages.  The pool is deliberately roomy: peak pages then measure
# the working-set footprint, not the pool cap.
SP_N_REQUESTS = 16
SP_HEADERS = 2              # distinct system headers in the trace
SP_HEADER_PAGES = 2         # header length = 2 full pages (32 tokens)
SP_TAIL_LENS = (2, 8)       # per-request unique suffix length
SP_MAX_NEW = (2, 8)
SP_RATE = 0.6               # arrivals per decode step
SP_POOL_PAGES = 48

# fault replay: a contended satellite engine (small pool, big staging
# reserve — spills are constant, so spill corruption has records to
# hit) under a dense pass schedule, with every fault class armed at
# once.  Rates are high enough that a short replay still draws several
# losses AND corruptions from the seeded stream; frames are small so
# even compact result payloads span frames.
FR_N_REQUESTS = 8
FR_SEED = 0                 # FaultPlan seed for the gated section
FR_FRAME_LOSS = 0.25        # per-frame transmit erasure probability
FR_FRAME_CORRUPT = 0.2      # per-frame bit-flip probability
FR_TRUNCATE_EVERY = 3       # every 3rd pass ends early (LOS)
FR_SPILL_CORRUPT_EVERY = 2  # every 2nd spill-store merge lands corrupted
FR_CRASH_AT_TICK = 25       # scheduled onboard reboot
FR_FRAME_BYTES = 32         # downlink ARQ frame size
FR_MAX_RETRIES = 6          # per-frame retry budget
FR_CHECKPOINT_EVERY = 8     # onboard ticks between checkpoints
FR_SAT_SLOTS = 2
FR_SAT_POOL_PAGES = 9
FR_SAT_PAGE_SIZE = 8
FR_RESERVE_PAGES = 4
FR_GATE_THRESHOLD = 0.6     # mixed escalation (raw + compact payloads)

# speculative replay: (a) the VERIFY micro-bench serves the same trace
# twice through the continuous engine — plain decode vs requests
# carrying their own plain-run output as a draft stream (perfect
# acceptance), so the accepted-token throughput gate measures exactly
# the one-chunk-pass-vs-k-decode-dispatches win; (b) the CASCADE
# replay reruns a space-ground trace with prompts much longer than
# answers twice — raw-prompt escalation vs draft-id escalation
# (speculative=True) — and gates bytes-per-escalation plus ground-tier
# verify latency.  Both tiers share params, so the satellite's answers
# are exactly the ground's greedy continuations and every shipped
# draft is accepted (the repo's preempt/chunk exactness gates are what
# make that guarantee hold under contention).
SD_N_REQUESTS = 6
SD_SLOTS = 2
SD_PROMPTS = (8, 16)
SD_MAX_NEW = 32             # fixed decode budget per request
SD_DRAFT_K = 8              # drafts verified per slot per tick
SC_N_REQUESTS = 6
SC_PROMPTS = (24, 40)       # prompts longer than answers: the raw
SC_MAX_NEW = (6, 12)        # escalation payload dwarfs the draft ids
SC_GATE_THRESHOLD = 0.9     # escalate (nearly) everything: the section
                            # is about the escalated path's cost

# constellation replay: K=3 satellites on one shared tick clock, M=2
# ground stations, ALL load uplinked via satellite 0 — whose plane sees
# its home station once (~t=189 of the 600 s horizon at these
# densities) while its peers get a pass every minute or two.  The value
# planner + handover move satellite 0's backlog over the ISL and
# deliver inside the peers' early passes; the static independent-pairs
# comparator parks every answer until the lone home-station pass.
CN_N_SATS = 3
CN_N_STATIONS = 2
CN_N_REQUESTS = 8
CN_PROMPTS = (6, 12)
CN_MAX_NEW = (4, 10)
CN_HORIZON_S = 600.0
CN_CONTACT_DURATION_S = 6.0
CN_CONTACTS_PER_DAY = (144, 2400, 2400)
CN_SCHEDULE_SEED = 3
CN_MARGIN_TICKS = 16        # peer's pass must beat the owner's by this
CN_SLOTS = 2
CN_PAGE_SIZE = 8
CN_POOL_PAGES = 12
CN_FRAME_BYTES = 256        # chaos lane: framed ARQ on downlink + ISL
CN_MAX_RETRIES = 6
CN_FRAME_LOSS = 0.2
CN_FRAME_CORRUPT = 0.15
CN_SPILL_CORRUPT_EVERY = 3
CN_FAULT_SEED = 11          # the CI chaos step's constellation seed

# sharded replay: fp32 configs (cross-device psum must not reorder a
# reduction into a different greedy argmax) with head counts that
# divide a 4-way model axis.  The dense lane is timed A/B (warmed jit
# caches) for the throughput gate; the MoE lane is about expert
# dispatch accounting, not wall time, so it runs cold.
SH_N_REQUESTS = 12
SH_TIMED_REPS = 3           # best-of-N walls for the parity gate: the
                            # replays are sub-second, so a single rep
                            # is scheduler-noise-limited
SH_MOE_N_REQUESTS = 6
SH_SEED = 11                # dense-lane poisson trace seed
SH_MOE_SEED = 13
SH_FORCED_DEVICES = 4       # --sharded lane's forced host device count


def _make_engine_inputs():
    from repro.config import get_reduced_config
    from repro.serving.batching import poisson_trace

    cfg = get_reduced_config("smollm-360m")
    trace = poisson_trace(N_REQUESTS, rate=ARRIVAL_RATE,
                          prompt_lens=PROMPT_LENS, max_new=MAX_NEW,
                          vocab_size=cfg.vocab_size, seed=7)
    return cfg, trace


def _clone(trace):
    return [r.clone() for r in trace]


def _serve_fixed(cfg, params, trace):
    """Fixed-slot baseline: the seed ``RequestQueue.next_batch``
    discipline (FIFO, pad to the batch's longest prompt) with each batch
    decoded for its max ``max_new``.  The clock (in decode steps) only
    advances while the batch drains, so a new batch forms from whatever
    has arrived by then.  Returns (useful_tokens, wall_seconds, kv_stats,
    emitted_tokens) like ``_serve_continuous`` — the last two are empty/
    None placeholders (no KV accounting or exactness check here)."""
    from repro.serving.batching import RequestQueue
    from repro.serving.engine import ServingEngine

    eng = ServingEngine(cfg, params, max_seq=MAX_SEQ)
    queue = RequestQueue(max_batch=N_SLOTS)
    pending = sorted(trace, key=lambda r: r.arrival_t)
    clock, useful = 0.0, 0
    t0 = time.perf_counter()
    while pending or len(queue):
        while pending and pending[0].arrival_t <= clock:
            queue.submit(pending.pop(0))
        batch = queue.next_batch()
        if batch is None:
            clock += 1.0                       # idle tick
            continue
        steps = max(r.max_new for r in batch.requests)
        eng.generate(batch.tokens, max_new=steps)
        useful += sum(r.max_new for r in batch.requests)
        clock += steps
    return useful, time.perf_counter() - t0, {}, None


def _serve_continuous(cfg, params, trace, kv_layout):
    from repro.serving.engine import ContinuousEngine

    kw = {"page_size": PAGE_SIZE} if kv_layout == "paged" else {}
    eng = ContinuousEngine(cfg, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                           kv_layout=kv_layout, **kw)
    t0 = time.perf_counter()
    results = eng.run(_clone(trace))
    wall = time.perf_counter() - t0
    useful = sum(len(r.tokens) for r in results.values())
    tokens_by_order = [results[k].tokens for k in sorted(results)]
    return useful, wall, eng.kv_cache_stats(), tokens_by_order


def _in_window(clock: int) -> bool:
    return clock % CW_PERIOD < CW_DURATION


def _serve_preemptive(cfg, params, trace):
    """Contact-window replay: spill every in-flight sequence at window
    open, resume token-exactly after the pass."""
    from repro.serving.engine import ContinuousEngine
    from repro.serving.scheduler import PreemptiveScheduler

    eng = ContinuousEngine(cfg, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                           kv_layout="paged", page_size=PAGE_SIZE)
    sched = PreemptiveScheduler(eng, preempt_mode="spill")
    for r in sorted(trace, key=lambda r: r.arrival_t):
        sched.submit(r)
    t0 = time.perf_counter()
    while sched.has_work():
        if _in_window(eng.clock):
            sched.preempt_all()
            sched.step(decode=False)
        else:
            sched.step()
        if eng.clock > CW_MAX_STEPS:
            raise RuntimeError("contact-window replay did not drain")
    wall = time.perf_counter() - t0
    alloc = eng.slots.allocator
    return {
        "results": eng.results,
        "wall_s": wall,
        "clock_steps": eng.clock,
        "pool_drained": alloc.in_use == 0 and alloc.reserved == 0,
        **sched.stats(),
    }


def _serve_restart(cfg, params, trace):
    """No-preemption baseline: in-flight sequences are aborted at window
    open (pages released, progress discarded) and re-decoded from
    scratch after the pass."""
    from repro.serving.engine import ContinuousEngine

    eng = ContinuousEngine(cfg, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                           kv_layout="paged", page_size=PAGE_SIZE)
    for r in sorted(trace, key=lambda r: r.arrival_t):
        eng.submit(r)
    n_aborts = wasted_tokens = 0
    t0 = time.perf_counter()
    while len(eng.queue) or eng.slots.any_active():
        if _in_window(eng.clock):
            aborted = [eng.slots.detach(slot, release_pages=True)
                       for slot in eng.slots.active_slots()]
            for st in reversed(aborted):              # keep admission order
                eng.queue.requeue_front(st.request)   # redo from prefill
                n_aborts += 1
                wasted_tokens += len(st.emitted)
            eng._idle_tick()                          # pass holds the compute
        else:
            eng.step()
        if eng.clock > CW_MAX_STEPS:
            raise RuntimeError("restart replay did not drain")
    wall = time.perf_counter() - t0
    alloc = eng.slots.allocator
    return {
        "results": eng.results,
        "wall_s": wall,
        "clock_steps": eng.clock,
        "pool_drained": alloc.in_use == 0 and alloc.reserved == 0,
        "n_aborts": n_aborts,
        "wasted_tokens": wasted_tokens,
    }


def _in_ov_window(clock: int) -> bool:
    return clock % OV_PERIOD < OV_DURATION


def _serve_overlap(cfg, params, trace, *, overlap):
    """Overlap replay under the dense window schedule.  ``overlap=False``
    is the stop-the-world comparator: all decode preempted for every
    pass.  ``overlap=True`` keeps decoding through passes and only
    spills the sequences whose pages must cover the transmit lane's
    staging reserve — with KV-delta re-spills."""
    from repro.serving.engine import ContinuousEngine
    from repro.serving.scheduler import PreemptiveScheduler

    eng = ContinuousEngine(cfg, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                           kv_layout="paged", page_size=PAGE_SIZE)
    sched = PreemptiveScheduler(eng, preempt_mode="spill", delta_spill=True)
    for r in sorted(trace, key=lambda r: r.arrival_t):
        sched.submit(r)
    decode_steps_in_window = 0
    t0 = time.perf_counter()
    while sched.has_work():
        if _in_ov_window(eng.clock):
            if overlap:
                sched.hold_pages(OV_RESERVE_PAGES)
                finished = sched.step()    # compute lane keeps running
            else:
                sched.preempt_all()
                finished = sched.step(decode=False)
            # counted for BOTH branches, AFTER the step (it may
            # resume/admit and then decode in the same tick): the
            # stop-the-world run must measure 0 here, not skip the
            # measurement — the gate then really tests the comparator
            decode_steps_in_window += int(bool(finished)
                                          or eng.slots.any_active())
        else:
            sched.release_hold()
            sched.step()
        if eng.clock > CW_MAX_STEPS:
            raise RuntimeError("overlap replay did not drain")
    sched.release_hold()
    wall = time.perf_counter() - t0
    alloc = eng.slots.allocator
    return {
        "results": eng.results,
        "wall_s": wall,
        "clock_steps": eng.clock,
        "decode_steps_in_window": decode_steps_in_window,
        "pool_drained": alloc.in_use == 0 and alloc.reserved == 0,
        "spill_store_empty": sched.store is None or len(sched.store) == 0,
        **sched.stats(),
    }


def _overlap_report(cfg, params, trace, reference_tokens):
    """Overlapped vs stop-the-world on the SAME dense schedule, both
    compared token-for-token against the uninterrupted run."""
    ov = _serve_overlap(cfg, params, _clone(trace), overlap=True)
    stw = _serve_overlap(cfg, params, _clone(trace), overlap=False)

    def summarize(run):
        results = run.pop("results")
        tokens = [results[k].tokens for k in sorted(results)]
        useful = sum(len(t) for t in tokens)
        run["useful_tokens"] = useful
        run["goodput_tokens_per_step"] = round(useful / run["clock_steps"], 4)
        run["tokens_per_s"] = round(useful / run["wall_s"], 2)
        run["wall_s"] = round(run["wall_s"], 4)
        return tokens

    ov_tokens = summarize(ov)
    stw_tokens = summarize(stw)
    exact = lambda toks: (len(toks) == len(reference_tokens) and all(
        np.array_equal(a, b) for a, b in zip(toks, reference_tokens)))
    return {
        "windows": {"period_steps": OV_PERIOD, "duration_steps": OV_DURATION,
                    "comm_reserve_pages": OV_RESERVE_PAGES},
        "overlapped": ov,
        "stop_the_world": stw,
        "token_exact_vs_uninterrupted": exact(ov_tokens),
        "stop_the_world_token_exact": exact(stw_tokens),
        "goodput_ratio_vs_stop_the_world": round(
            ov["goodput_tokens_per_step"] / stw["goodput_tokens_per_step"],
            3),
        "delta_spill_bytes": ov["spill_bytes"],
        "full_spill_bytes_equiv": ov["spill_bytes_full_equiv"],
    }


def _contact_window_report(cfg, params, trace, reference_tokens):
    """Run both replays and compare against the uninterrupted tokens
    (keyed by submission order, rids differ across engines)."""
    pre = _serve_preemptive(cfg, params, _clone(trace))
    res = _serve_restart(cfg, params, _clone(trace))

    def summarize(run):
        results = run.pop("results")
        tokens = [results[k].tokens for k in sorted(results)]
        useful = sum(len(t) for t in tokens)
        run["useful_tokens"] = useful
        run["goodput_tokens_per_step"] = round(useful / run["clock_steps"], 4)
        run["tokens_per_s"] = round(useful / run["wall_s"], 2)
        run["wall_s"] = round(run["wall_s"], 4)
        return tokens

    pre_tokens = summarize(pre)
    res_tokens = summarize(res)
    exact = lambda toks: (len(toks) == len(reference_tokens) and all(
        np.array_equal(a, b) for a, b in zip(toks, reference_tokens)))
    return {
        "windows": {"period_steps": CW_PERIOD, "duration_steps": CW_DURATION},
        "preemptive": pre,
        "restart": res,
        "token_exact_vs_uninterrupted": exact(pre_tokens),
        "restart_token_exact": exact(res_tokens),
        "goodput_ratio": round(pre["goodput_tokens_per_step"]
                               / res["goodput_tokens_per_step"], 3),
    }


def _heavy_tail_trace(cfg):
    """Poisson arrivals with a heavy-tail prompt-length mix: every
    ``HT_HEAVY_EVERY``-th request carries a near-max_seq prompt."""
    from repro.serving.batching import Request

    rng = np.random.default_rng(23)
    t, out = 0.0, []
    for i in range(HT_N_REQUESTS):
        t += float(rng.exponential(1.0 / HT_RATE))
        lens = (HT_HEAVY_PROMPTS if i % HT_HEAVY_EVERY == HT_HEAVY_EVERY - 1
                else HT_LIGHT_PROMPTS)
        S = int(rng.integers(lens[0], lens[1] + 1))
        out.append(Request(
            prompt=rng.integers(1, cfg.vocab_size, S).astype(np.int32),
            max_new=int(rng.integers(HT_MAX_NEW[0], HT_MAX_NEW[1] + 1)),
            arrival_t=t))
    return out


def _serve_budgeted(cfg, params, trace, budget):
    """Replay the heavy-tail trace through one engine, timing EVERY
    unified-step tick.  budget=None is the monolithic comparator (whole
    prompts land in a single chunk, stalling their tick)."""
    from repro.serving.engine import ContinuousEngine

    eng = ContinuousEngine(cfg, params, n_slots=N_SLOTS, max_seq=HT_MAX_SEQ,
                           kv_layout="paged", page_size=PAGE_SIZE,
                           prefill_budget_tokens=budget)
    by_rid = {}
    for r in sorted(trace, key=lambda r: r.arrival_t):
        eng.submit(r)
        by_rid[r.rid] = r
    import jax

    tick_s = []
    max_prefill = 0
    while len(eng.queue) or eng.slots.any_active():
        t0 = time.perf_counter()
        eng.step()
        # async dispatch would bill a tick's model work to whichever
        # later tick first syncs on a result — block so each tick's
        # latency is its own
        jax.block_until_ready(eng.slots.cache)
        tick_s.append(time.perf_counter() - t0)
        max_prefill = max(max_prefill, eng.last_tick_prefill_tokens)
    results = eng.results
    tokens = [results[k].tokens for k in sorted(results)]
    ttft = [results[r.rid].first_token_step - r.arrival_t
            for r in by_rid.values()]
    lat = np.asarray(tick_s)
    alloc = eng.slots.allocator
    return {
        "n_ticks": len(tick_s),
        "useful_tokens": int(sum(len(t) for t in tokens)),
        "tick_latency_p50_s": round(float(np.percentile(lat, 50)), 6),
        "tick_latency_p99_s": round(float(np.percentile(lat, 99)), 6),
        "tick_latency_max_s": round(float(lat.max()), 6),
        "ttft_mean_steps": round(float(np.mean(ttft)), 2),
        "ttft_p99_steps": round(float(np.percentile(ttft, 99)), 2),
        "max_prefill_tokens_per_tick": int(max_prefill),
        "pool_drained": alloc.in_use == 0 and alloc.reserved == 0,
    }, tokens


def _chunked_prefill_report(cfg, params):
    """Chunked (budgeted) vs monolithic (unbounded) unified step on the
    SAME heavy-tail trace: token-exact, with the chunked run's tail tick
    latency strictly below the monolithic run's."""
    trace = _heavy_tail_trace(cfg)
    runs = {}
    tokens = {}
    for name, budget in (("chunked", PREFILL_BUDGET), ("monolithic", None)):
        _serve_budgeted(cfg, params, _clone(trace), budget)   # warm jit
        runs[name], tokens[name] = _serve_budgeted(cfg, params,
                                                   _clone(trace), budget)
    return {
        "trace": {"n_requests": HT_N_REQUESTS, "max_seq": HT_MAX_SEQ,
                  "light_prompts": list(HT_LIGHT_PROMPTS),
                  "heavy_prompts": list(HT_HEAVY_PROMPTS),
                  "heavy_every": HT_HEAVY_EVERY,
                  "prefill_budget_tokens": PREFILL_BUDGET},
        "chunked": runs["chunked"],
        "monolithic": runs["monolithic"],
        "token_exact": (len(tokens["chunked"]) == len(tokens["monolithic"])
                        and all(np.array_equal(a, b)
                                for a, b in zip(tokens["chunked"],
                                                tokens["monolithic"]))),
        "tick_p99_ratio": round(
            runs["chunked"]["tick_latency_p99_s"]
            / max(runs["monolithic"]["tick_latency_p99_s"], 1e-12), 4),
    }


def _shared_prefix_trace(cfg):
    """Poisson arrivals where every prompt = one of ``SP_HEADERS``
    shared system headers (``SP_HEADER_PAGES`` full pages) + a short
    unique tail.  Request 0 of each header is the cold miss that seeds
    the index; every later reuse is a page-granular hit."""
    from repro.serving.batching import Request

    rng = np.random.default_rng(11)
    headers = [rng.integers(1, cfg.vocab_size,
                            SP_HEADER_PAGES * PAGE_SIZE).astype(np.int32)
               for _ in range(SP_HEADERS)]
    t, out = 0.0, []
    for i in range(SP_N_REQUESTS):
        t += float(rng.exponential(1.0 / SP_RATE))
        tail = rng.integers(
            1, cfg.vocab_size,
            int(rng.integers(SP_TAIL_LENS[0],
                             SP_TAIL_LENS[1] + 1))).astype(np.int32)
        out.append(Request(
            prompt=np.concatenate([headers[i % SP_HEADERS], tail]),
            max_new=int(rng.integers(SP_MAX_NEW[0], SP_MAX_NEW[1] + 1)),
            arrival_t=t))
    return out


def _serve_shared(cfg, params, trace, *, prefix_cache):
    """One replay of the shared-prefix trace; returns (summary dict,
    emitted tokens).  Peak KV bytes are the high-water page count times
    the per-page byte cost — both runs size the pool identically, so
    the pool-allocation bytes cancel and the peak measures footprint."""
    from repro.serving.engine import ContinuousEngine

    eng = ContinuousEngine(cfg, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                           kv_layout="paged", page_size=PAGE_SIZE,
                           pool_pages=SP_POOL_PAGES,
                           prefix_cache=prefix_cache)
    t0 = time.perf_counter()
    results = eng.run(_clone(trace))
    wall = time.perf_counter() - t0
    tokens = [results[k].tokens for k in sorted(results)]
    stats = eng.kv_cache_stats()
    alloc = eng.slots.allocator
    live_refs = alloc.n_live_refs()
    if eng.slots.prefix_index is not None:
        eng.slots.prefix_index.clear()     # end of life: drop cached pages
    out = {
        "useful_tokens": int(sum(len(t) for t in tokens)),
        "wall_s": round(wall, 4),
        "prefill_tokens_total": eng.prefill_tokens_total,
        "kv_peak_bytes": (stats["peak_pages_in_use"]
                          * (stats["kv_cache_bytes"]
                             // (SP_POOL_PAGES + 1))),
        "live_refs_before_clear": live_refs,
        "pool_drained": (alloc.in_use == 0 and alloc.reserved == 0
                         and alloc.n_live_refs() == 0),
        **{k: v for k, v in stats.items() if k != "kv_cache_bytes"},
    }
    return out, tokens


def _shared_prefix_report(cfg, params):
    """prefix_cache=True vs =False on the SAME header-heavy trace:
    token-exact, with the shared run's peak KV bytes and prefill tokens
    both strictly below the unshared run's."""
    trace = _shared_prefix_trace(cfg)
    runs, tokens = {}, {}
    for name, pc in (("shared", True), ("unshared", False)):
        _serve_shared(cfg, params, _clone(trace), prefix_cache=pc)  # warm jit
        runs[name], tokens[name] = _serve_shared(cfg, params, _clone(trace),
                                                 prefix_cache=pc)
    return {
        "trace": {"n_requests": SP_N_REQUESTS, "n_headers": SP_HEADERS,
                  "header_pages": SP_HEADER_PAGES,
                  "tail_lens": list(SP_TAIL_LENS),
                  "max_new": list(SP_MAX_NEW),
                  "pool_pages": SP_POOL_PAGES},
        "shared": runs["shared"],
        "unshared": runs["unshared"],
        "token_exact": (len(tokens["shared"]) == len(tokens["unshared"])
                        and all(np.array_equal(a, b)
                                for a, b in zip(tokens["shared"],
                                                tokens["unshared"]))),
        "kv_peak_bytes_ratio": round(
            runs["shared"]["kv_peak_bytes"]
            / max(runs["unshared"]["kv_peak_bytes"], 1), 4),
        "prefill_tokens_ratio": round(
            runs["shared"]["prefill_tokens_total"]
            / max(runs["unshared"]["prefill_tokens_total"], 1), 4),
    }


def _fault_trace(cfg):
    from repro.serving.batching import Request

    rng = np.random.default_rng(3)
    return [Request(
        prompt=rng.integers(1, cfg.vocab_size,
                            int(rng.integers(8, 14))).astype(np.int32),
        max_new=int(rng.integers(10, 18)), arrival_t=float(i * 2))
        for i in range(FR_N_REQUESTS)]


def _serve_fault(cfg, params, trace, *, plan_seed=None):
    """One space-ground replay; ``plan_seed=None`` is the fault-free
    comparator (same engines/schedule/gate, no injector, unframed
    lane).  Returns (summary dict, final tokens by submission order)."""
    from repro.core.faults import FaultInjector, FaultPlan
    from repro.core.gating import ConfidenceGate
    from repro.core.link import ContactSchedule
    from repro.serving.engine import ContinuousEngine
    from repro.serving.scheduler import SpaceGroundScheduler

    sat = ContinuousEngine(cfg, params, n_slots=FR_SAT_SLOTS,
                           max_seq=MAX_SEQ, kv_layout="paged",
                           page_size=FR_SAT_PAGE_SIZE,
                           pool_pages=FR_SAT_POOL_PAGES,
                           prefill_budget_tokens=8)
    gnd = ContinuousEngine(cfg, params, n_slots=FR_SAT_SLOTS,
                           max_seq=MAX_SEQ)
    kw = dict(schedule=ContactSchedule(contact_duration_s=4.0,
                                       contacts_per_day=8640, seed=3),
              gate=ConfidenceGate("max_prob", FR_GATE_THRESHOLD),
              s_per_step=1.0, horizon_s=7200.0,
              comm_reserve_pages=FR_RESERVE_PAGES)
    inj = None
    if plan_seed is not None:
        inj = FaultInjector(FaultPlan(
            seed=plan_seed, frame_loss_rate=FR_FRAME_LOSS,
            frame_corrupt_rate=FR_FRAME_CORRUPT,
            truncate_every=FR_TRUNCATE_EVERY,
            spill_corrupt_every=FR_SPILL_CORRUPT_EVERY,
            crash_at_tick=FR_CRASH_AT_TICK))
        kw.update(faults=inj, frame_bytes=FR_FRAME_BYTES,
                  link_max_retries=FR_MAX_RETRIES,
                  checkpoint_every=FR_CHECKPOINT_EVERY)
    sg = SpaceGroundScheduler(sat, gnd, **kw)
    t0 = time.perf_counter()
    rep = sg.run([r.clone() for r in trace])
    wall = time.perf_counter() - t0
    tokens = [rep.tokens[k] for k in sorted(rep.tokens)]
    sat_tokens = [rep.sat_results[k].tokens for k in sorted(rep.sat_results)]
    alloc = sg.sat.engine.slots.allocator
    ls = rep.lane_stats
    store_stats = (sg.sat.store.stats() if sg.sat.store is not None
                   else {})
    out = {
        "wall_s": round(wall, 4),
        "clock_steps": sg.sat.clock,
        "n_answers": len(tokens),
        "n_escalated": len(rep.escalated),
        "n_undelivered": len(rep.undelivered),
        "n_reboots": rep.n_reboots,
        "n_redo_from_corruption": rep.sat_stats["n_redo_from_corruption"],
        "pool_drained": (alloc.in_use == 0 and alloc.reserved == 0
                         and alloc.n_live_refs() == 0),
        "spill_store_empty": (sg.sat.store is None
                              or len(sg.sat.store) == 0),
        "lane": ls,
        "ledger": {k: round(v, 4) for k, v in
                   rep.ledger.counters.items()},
    }
    if inj is not None:
        attempted = max(ls["frame_bytes_attempted"], 1e-9)
        out["injected"] = {
            "n_frames_lost": inj.n_frames_lost,
            "n_frame_corruptions": inj.n_frame_corruptions,
            "n_spill_corruptions": inj.n_spill_corruptions,
            "n_corruptions_injected": inj.n_corruptions_injected,
            "n_windows_truncated": inj.n_windows_truncated,
            "n_crashes": inj.n_crashes,
        }
        out["n_corruptions_detected"] = (
            ls["n_corruptions_detected"]
            + store_stats.get("n_spill_corruptions_detected", 0))
        out["frame_ledger_conserved"] = bool(
            abs(ls["frame_bytes_attempted"]
                - (ls["bytes_sent"] + ls["bytes_lost"]
                   + ls["bytes_corrupt"])) < 1e-6)
        out["goodput_efficiency"] = round(ls["bytes_sent"] / attempted, 4)
    return out, tokens, sat_tokens


def _fault_replay_report(cfg, params, *, plan_seed=FR_SEED):
    """Fault-free vs all-faults-armed replay of the same trace: the
    fault plan must cost bytes and time, never answers."""
    trace = _fault_trace(cfg)
    ref, ref_tokens, ref_sat = _serve_fault(cfg, params, trace)
    flt, flt_tokens, flt_sat = _serve_fault(cfg, params, trace,
                                            plan_seed=plan_seed)
    exact = lambda a, b: (len(a) == len(b)
                          and all(np.array_equal(x, y)
                                  for x, y in zip(a, b)))
    return {
        "plan": {"seed": plan_seed, "frame_loss_rate": FR_FRAME_LOSS,
                 "frame_corrupt_rate": FR_FRAME_CORRUPT,
                 "truncate_every": FR_TRUNCATE_EVERY,
                 "spill_corrupt_every": FR_SPILL_CORRUPT_EVERY,
                 "crash_at_tick": FR_CRASH_AT_TICK,
                 "frame_bytes": FR_FRAME_BYTES,
                 "max_retries": FR_MAX_RETRIES,
                 "checkpoint_every": FR_CHECKPOINT_EVERY},
        "fault_free": ref,
        "faulted": flt,
        "token_exact_vs_fault_free": exact(flt_tokens, ref_tokens),
        "sat_token_exact_vs_fault_free": exact(flt_sat, ref_sat),
    }


def _spec_verify_requests(cfg, drafts=None):
    from repro.serving.batching import Request

    rng = np.random.default_rng(17)
    reqs = []
    for i in range(SD_N_REQUESTS):
        S = int(rng.integers(SD_PROMPTS[0], SD_PROMPTS[1] + 1))
        reqs.append(Request(
            prompt=rng.integers(1, cfg.vocab_size, S).astype(np.int32),
            max_new=SD_MAX_NEW,
            draft_toks=None if drafts is None else drafts[i]))
    return reqs


def _spec_verify_run(cfg, params, drafts=None):
    from repro.serving.engine import ContinuousEngine

    eng = ContinuousEngine(cfg, params, n_slots=SD_SLOTS, max_seq=MAX_SEQ,
                           page_size=PAGE_SIZE, draft_k=SD_DRAFT_K)
    reqs = _spec_verify_requests(cfg, drafts)
    t0 = time.perf_counter()
    results = eng.run(reqs)
    wall = time.perf_counter() - t0
    toks = [results[k].tokens for k in sorted(results)]
    useful = sum(len(t) for t in toks)
    alloc = eng.slots.allocator
    return {"useful_tokens": useful, "wall_s": round(wall, 4),
            "tokens_per_s": round(useful / wall, 2),
            "clock_steps": eng.clock,
            "pool_drained": alloc.in_use == 0 and alloc.reserved == 0,
            **eng.spec_stats()}, toks


def _spec_cascade_trace(cfg):
    from repro.serving.batching import Request

    rng = np.random.default_rng(5)
    return [Request(
        prompt=rng.integers(
            1, cfg.vocab_size,
            int(rng.integers(SC_PROMPTS[0], SC_PROMPTS[1] + 1)),
        ).astype(np.int32),
        max_new=int(rng.integers(SC_MAX_NEW[0], SC_MAX_NEW[1] + 1)),
        arrival_t=float(i * 2)) for i in range(SC_N_REQUESTS)]


def _serve_spec_cascade(cfg, params, trace, *, speculative):
    """One space-ground replay of the cascade trace; ``speculative``
    switches the escalation payload (raw prompt re-decode vs draft-id
    verification) and NOTHING else — same engines, schedule, gate."""
    from repro.core.gating import ConfidenceGate
    from repro.core.link import ContactSchedule
    from repro.serving.engine import ContinuousEngine
    from repro.serving.scheduler import SpaceGroundScheduler

    sat = ContinuousEngine(cfg, params, n_slots=SD_SLOTS, max_seq=MAX_SEQ,
                           prefill_budget_tokens=8)
    gnd = ContinuousEngine(cfg, params, n_slots=SD_SLOTS, max_seq=MAX_SEQ,
                           draft_k=SD_DRAFT_K)
    sg = SpaceGroundScheduler(
        sat, gnd,
        schedule=ContactSchedule(contact_duration_s=4.0,
                                 contacts_per_day=8640, seed=3),
        gate=ConfidenceGate("max_prob", SC_GATE_THRESHOLD),
        s_per_step=1.0, horizon_s=7200.0,
        comm_reserve_pages=FR_RESERVE_PAGES, speculative=speculative)
    t0 = time.perf_counter()
    rep = sg.run([r.clone() for r in trace])
    wall = time.perf_counter() - t0
    tokens = [rep.tokens[k] for k in sorted(rep.tokens)]
    led = rep.ledger
    n_esc = max(int(led.get("items_escalated")), 1)
    esc_key = ("bytes_draft_escalated" if speculative
               else "bytes_raw_escalated")
    glat = [r.finished_step - r.admitted_step
            for r in rep.ground_results.values()]
    sat_alloc, gnd_alloc = sat.slots.allocator, gnd.slots.allocator
    return {
        "wall_s": round(wall, 4),
        "n_escalated": len(rep.escalated),
        "n_undelivered": len(rep.undelivered),
        "bytes_escalated": round(led.get(esc_key), 1),
        "bytes_per_escalation": round(led.get(esc_key) / n_esc, 2),
        "ground_latency_mean_steps": round(float(np.mean(glat)), 3)
        if glat else 0.0,
        "pool_drained": all(a.in_use == 0 and a.reserved == 0
                            for a in (sat_alloc, gnd_alloc)),
        "spec": rep.spec_stats,
        "ledger": {k: round(v, 4) for k, v in led.counters.items()},
    }, tokens


def _speculative_report(cfg, params):
    """The GATE_VERSION 6 section: draft-verify in the unified step.

    verify: same engine, same trace, plain decode vs perfect
    self-drafts — token-exact, and accepted-token throughput must not
    fall below plain decode's tokens/s (one chunk pass replaces up to
    ``SD_DRAFT_K + 1`` decode dispatches).
    cascade: raw-prompt vs draft-id escalation on one space-ground
    trace — token-exact, strictly fewer bytes per escalation, and the
    ground tier answers escalations in strictly fewer ticks."""
    exact = lambda a, b: (len(a) == len(b)
                          and all(np.array_equal(x, y)
                                  for x, y in zip(a, b)))
    _spec_verify_run(cfg, params)                  # warmup (jit)
    plain, plain_toks = _spec_verify_run(cfg, params)
    drafts = [np.asarray(t, np.int32) for t in plain_toks]
    _spec_verify_run(cfg, params, drafts)          # warmup verify chunks
    spec, spec_toks = _spec_verify_run(cfg, params, drafts)

    trace = _spec_cascade_trace(cfg)
    raw_cas, raw_toks = _serve_spec_cascade(cfg, params, trace,
                                            speculative=False)
    spec_cas, spec_cas_toks = _serve_spec_cascade(cfg, params, trace,
                                                  speculative=True)
    return {
        "draft_k": SD_DRAFT_K,
        "verify": {
            "plain": plain,
            "speculative": spec,
            "token_exact": exact(spec_toks, plain_toks),
            "throughput_ratio": round(spec["tokens_per_s"]
                                      / plain["tokens_per_s"], 3),
        },
        "cascade": {
            "trace": {"n_requests": SC_N_REQUESTS,
                      "prompt_lens": list(SC_PROMPTS),
                      "max_new": list(SC_MAX_NEW),
                      "gate_threshold": SC_GATE_THRESHOLD},
            "raw": raw_cas,
            "speculative": spec_cas,
            "token_exact_vs_raw": exact(spec_cas_toks, raw_toks),
        },
    }


def _constellation_trace(cfg):
    from repro.serving.batching import Request

    rng = np.random.default_rng(9)
    return [Request(
        prompt=rng.integers(1, cfg.vocab_size,
                            int(rng.integers(*CN_PROMPTS))).astype(np.int32),
        max_new=int(rng.integers(CN_MAX_NEW[0], CN_MAX_NEW[1] + 1)),
        arrival_t=float(i)) for i in range(CN_N_REQUESTS)]


def _constellation_engine(cfg, params):
    from repro.serving.engine import ContinuousEngine

    return ContinuousEngine(cfg, params, n_slots=CN_SLOTS, max_seq=MAX_SEQ,
                            kv_layout="paged", page_size=CN_PAGE_SIZE,
                            pool_pages=CN_POOL_PAGES,
                            prefill_budget_tokens=16)


def _constellation_reference(cfg, params, trace):
    """Solo comparator: the same requests through ONE unconstrained
    engine — the token streams every constellation replay (with or
    without handovers) must reproduce exactly."""
    from repro.serving.scheduler import PreemptiveScheduler

    sched = PreemptiveScheduler(_constellation_engine(cfg, params))
    for r in trace:
        sched.submit(r.clone())
    while sched.has_work():
        sched.step()
    return [np.asarray(sched.results[k].tokens)
            for k in sorted(sched.results)]


def _serve_constellation(cfg, params, trace, *, policy, handover,
                         fault_seed=None):
    """One constellation replay of ``trace`` (every request uplinked
    via the window-poor satellite 0).  ``policy="static",
    handover=False`` is the K-independent-pairs comparator;
    ``fault_seed`` arms a lossy/corrupting fault plan on every framed
    lane (the chaos sweep).  Returns (summary, tokens in rid order)."""
    from repro.core.faults import FaultInjector, FaultPlan
    from repro.core.link import ContactSchedule
    from repro.serving.constellation import ConstellationScheduler

    engines = [_constellation_engine(cfg, params)
               for _ in range(CN_N_SATS)]
    ws = ContactSchedule(contact_duration_s=CN_CONTACT_DURATION_S,
                         contacts_per_day=CN_CONTACTS_PER_DAY[-1],
                         seed=CN_SCHEDULE_SEED).step_window_sets(
        1.0, CN_HORIZON_S, n_satellites=CN_N_SATS,
        n_stations=CN_N_STATIONS,
        contacts_per_day=list(CN_CONTACTS_PER_DAY))
    inj, kw = None, {}
    if fault_seed is not None:
        inj = FaultInjector(FaultPlan(
            seed=fault_seed, frame_loss_rate=CN_FRAME_LOSS,
            frame_corrupt_rate=CN_FRAME_CORRUPT,
            spill_corrupt_every=CN_SPILL_CORRUPT_EVERY))
        kw.update(faults=inj, frame_bytes=CN_FRAME_BYTES,
                  link_max_retries=CN_MAX_RETRIES)
    cs = ConstellationScheduler(engines, window_sets=ws,
                                n_stations=CN_N_STATIONS, s_per_step=1.0,
                                horizon_s=CN_HORIZON_S, policy=policy,
                                handover=handover,
                                handover_margin_ticks=CN_MARGIN_TICKS, **kw)
    assignments = [[r.clone() for r in trace]]
    assignments += [[] for _ in range(CN_N_SATS - 1)]
    t0 = time.perf_counter()
    rep = cs.run(assignments)
    wall = time.perf_counter() - t0
    toks = [rep.tokens[rid] for rid in sorted(rep.tokens)]
    out = {
        "wall_s": round(wall, 4),
        "policy": policy, "handover": handover,
        "final_clock": rep.final_clock,
        "delivered_tokens": rep.delivered_tokens,
        "goodput_tokens_per_tick": round(rep.goodput, 4),
        "n_undelivered": len(rep.undelivered),
        "n_handovers": rep.n_handovers,
        "n_result_forwards": rep.n_result_forwards,
        "n_handover_redos": rep.n_handover_redos,
        "assigned_pass_ticks": rep.assigned_pass_ticks,
        "pool_drained": all(e.slots.allocator.in_use == 0
                            and e.slots.allocator.reserved == 0
                            for e in engines),
        "spill_store_empty": all(len(s.store) == 0 for s in cs.sats),
        "lanes_empty": all(len(l) == 0 for l in [*cs.lanes, *cs.isl]),
        "within_energy_budget": rep.within_energy_budget,
        "energy_j": [round(cs.fleet.energy_j(k), 2)
                     for k in range(CN_N_SATS)],
        "fleet_totals": {k: round(v, 4)
                         for k, v in rep.fleet_totals.items()},
    }
    if inj is not None:
        out["injected"] = {
            "n_frames_lost": inj.n_frames_lost,
            "n_frame_corruptions": inj.n_frame_corruptions,
            "n_spill_corruptions": inj.n_spill_corruptions,
            "n_corruptions_injected": inj.n_corruptions_injected,
        }
        out["n_corruptions_detected"] = (
            sum(l["n_corruptions_detected"]
                for l in [*rep.lane_stats, *rep.isl_stats])
            + sum(s.store.stats().get("n_spill_corruptions_detected", 0)
                  for s in cs.sats if s.store is not None))
        out["n_silent_corruptions"] = sum(
            l["n_silent_corruptions"]
            for l in [*rep.lane_stats, *rep.isl_stats])
    return out, toks


def _constellation_report(cfg, params):
    """The GATE_VERSION 7 section: contact planning + token-exact
    handover vs K independent onboard/ground pairs on the same window
    sets.  Goodput is measured in delivered tokens per drain tick, so
    both replays are compared on schedule time, not wall time."""
    exact = lambda a, b: (len(a) == len(b)
                          and all(np.array_equal(x, y)
                                  for x, y in zip(a, b)))
    trace = _constellation_trace(cfg)
    want = _constellation_reference(cfg, params, trace)
    pooled, pooled_toks = _serve_constellation(
        cfg, params, trace, policy="value", handover=True)
    indep, indep_toks = _serve_constellation(
        cfg, params, trace, policy="static", handover=False)
    dl_pooled = pooled["fleet_totals"].get("bytes_downlinked", 0.0)
    dl_indep = indep["fleet_totals"].get("bytes_downlinked", 0.0)
    return {
        "trace": {"n_satellites": CN_N_SATS,
                  "n_stations": CN_N_STATIONS,
                  "n_requests": CN_N_REQUESTS,
                  "prompt_lens": list(CN_PROMPTS),
                  "max_new": list(CN_MAX_NEW),
                  "horizon_s": CN_HORIZON_S,
                  "contacts_per_day": list(CN_CONTACTS_PER_DAY),
                  "contact_duration_s": CN_CONTACT_DURATION_S,
                  "handover_margin_ticks": CN_MARGIN_TICKS,
                  "schedule_seed": CN_SCHEDULE_SEED},
        "pooled": pooled,
        "independent_pairs": indep,
        "token_exact_vs_solo": exact(pooled_toks, want),
        "independent_token_exact_vs_solo": exact(indep_toks, want),
        "goodput_ratio": round(
            pooled["goodput_tokens_per_tick"]
            / max(indep["goodput_tokens_per_tick"], 1e-9), 3),
        "downlink_bytes_ratio": round(dl_pooled / max(dl_indep, 1e-9), 4),
    }


def run_constellation_chaos(seeds):
    """The CI chaos sweep's constellation lane: handover under a lossy,
    corrupting fault plan (ARQ re-ships frames, corrupt spill records
    redo from prefill) must still deliver token-exact answers and drain
    every pool, store and lane."""
    import jax
    from repro.models import transformer as T

    cfg, _ = _make_engine_inputs()
    params = T.init_params(jax.random.PRNGKey(0), cfg, max_seq=MAX_SEQ)
    trace = _constellation_trace(cfg)
    want = _constellation_reference(cfg, params, trace)
    failures = []
    for seed in seeds:
        flt, toks = _serve_constellation(cfg, params, trace,
                                         policy="value", handover=True,
                                         fault_seed=seed)
        inj = flt["injected"]
        checks = {
            "token_exact": (len(toks) == len(want) and all(
                np.array_equal(a, b) for a, b in zip(toks, want))),
            "handovers": flt["n_handovers"] > 0,
            "all_delivered": flt["n_undelivered"] == 0,
            "detected": (inj["n_corruptions_injected"] == 0
                         or flt["n_corruptions_detected"] > 0),
            "no_silent": flt["n_silent_corruptions"] == 0,
            "drained": (flt["pool_drained"] and flt["spill_store_empty"]
                        and flt["lanes_empty"]),
        }
        bad = [k for k, ok in checks.items() if not ok]
        status = "ok" if not bad else f"FAIL({','.join(bad)})"
        print(f"constellation chaos seed={seed}: {status} "
              f"handovers={flt['n_handovers']} "
              f"redo={flt['n_handover_redos']} "
              f"injected={inj['n_corruptions_injected']} "
              f"detected={flt['n_corruptions_detected']} "
              f"clock={flt['final_clock']}")
        if bad:
            failures.append((seed, bad))
    return failures


def run_chaos(seeds):
    """The CI chaos sweep: replay the fault section under several
    FaultPlan seeds, holding the full invariant set for each."""
    import jax
    from repro.models import transformer as T

    cfg, _ = _make_engine_inputs()
    params = T.init_params(jax.random.PRNGKey(0), cfg, max_seq=MAX_SEQ)
    trace = _fault_trace(cfg)
    _, ref_tokens, ref_sat = _serve_fault(cfg, params, trace)
    failures = []
    for seed in seeds:
        flt, toks, sat_toks = _serve_fault(cfg, params, trace,
                                           plan_seed=seed)
        inj = flt["injected"]
        checks = {
            "token_exact": (len(toks) == len(ref_tokens) and all(
                np.array_equal(a, b) for a, b in zip(toks, ref_tokens))),
            "sat_token_exact": (len(sat_toks) == len(ref_sat) and all(
                np.array_equal(a, b)
                for a, b in zip(sat_toks, ref_sat))),
            "all_detected": (flt["n_corruptions_detected"]
                             == inj["n_corruptions_injected"]),
            "no_silent": flt["lane"]["n_silent_corruptions"] == 0,
            "conserved": flt["frame_ledger_conserved"],
            "rebooted": flt["n_reboots"] == 1 == inj["n_crashes"],
            "drained": flt["pool_drained"] and flt["spill_store_empty"],
            "all_delivered": flt["n_undelivered"] == 0,
        }
        bad = [k for k, ok in checks.items() if not ok]
        status = "ok" if not bad else f"FAIL({','.join(bad)})"
        print(f"chaos seed={seed}: {status} "
              f"injected={inj['n_corruptions_injected']} "
              f"detected={flt['n_corruptions_detected']} "
              f"lost={inj['n_frames_lost']} "
              f"retx={flt['lane']['n_retransmits']} "
              f"reboots={flt['n_reboots']} "
              f"redo={flt['n_redo_from_corruption']} "
              f"eff={flt['goodput_efficiency']}")
        if bad:
            failures.append((seed, bad))
    return failures


def _serve_mesh(cfg, params, trace, mesh):
    """One paged continuous replay, optionally on a device mesh.
    Returns (report_dict, tokens_by_rid_order) — the report carries the
    engine's full KV accounting (per-device bytes/pages, mesh axes,
    expert dispatch) so the gates read one flat dict per run."""
    from repro.serving.engine import ContinuousEngine

    eng = ContinuousEngine(cfg, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                           kv_layout="paged", page_size=PAGE_SIZE,
                           mesh=mesh)
    t0 = time.perf_counter()
    results = eng.run(_clone(trace))
    wall = time.perf_counter() - t0
    useful = sum(len(r.tokens) for r in results.values())
    alloc = eng.slots.allocator
    report = {"useful_tokens": useful, "wall_s": round(wall, 4),
              "tokens_per_s": round(useful / wall, 2),
              "pool_drained": alloc.in_use == 0 and alloc.reserved == 0,
              **eng.kv_cache_stats()}
    return report, [results[k].tokens for k in sorted(results)]


def _token_exact(a, b):
    return bool(len(a) == len(b)
                and all(np.array_equal(x, y) for x, y in zip(a, b)))


def _sharded_report():
    """Single-device vs mesh-sharded A/B on the same traces.

    The mesh spans every visible device (``make_serving_mesh()``): one
    device on the default bench lane, ``SH_FORCED_DEVICES`` under the
    ``--sharded`` CI lane.  The dense lane is warmed then timed for the
    throughput-parity gate; the MoE lane demonstrates expert-parallel
    serving prefill (per-device dispatch counts in the stats)."""
    import jax
    from repro.config import get_reduced_config
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer as T
    from repro.serving.batching import poisson_trace

    cfg = get_reduced_config("smollm-360m").with_(
        param_dtype="float32", activation_dtype="float32",
        n_heads=8, n_kv_heads=4, head_dim=32)
    params = T.init_params(jax.random.PRNGKey(0), cfg, max_seq=MAX_SEQ)
    trace = poisson_trace(SH_N_REQUESTS, rate=ARRIVAL_RATE,
                          prompt_lens=PROMPT_LENS, max_new=MAX_NEW,
                          vocab_size=cfg.vocab_size, seed=SH_SEED)
    mesh = make_serving_mesh()

    runs, toks = {}, {}
    for name, m in (("single_device", None), ("sharded", mesh)):
        _serve_mesh(cfg, params, trace, m)     # warmup: populate jit caches
        for _ in range(SH_TIMED_REPS):
            rep, toks[name] = _serve_mesh(cfg, params, trace, m)
            if name not in runs or rep["wall_s"] < runs[name]["wall_s"]:
                runs[name] = rep
    sh, sd = runs["sharded"], runs["single_device"]

    # expert-parallel MoE serving: same A/B, dispatch accounting gated
    moe_cfg = get_reduced_config("qwen3-moe-30b-a3b").with_(
        param_dtype="float32", activation_dtype="float32", n_kv_heads=4)
    moe_params = T.init_params(jax.random.PRNGKey(1), moe_cfg,
                               max_seq=MAX_SEQ)
    moe_trace = poisson_trace(SH_MOE_N_REQUESTS, rate=ARRIVAL_RATE,
                              prompt_lens=PROMPT_LENS, max_new=MAX_NEW,
                              vocab_size=moe_cfg.vocab_size,
                              seed=SH_MOE_SEED)
    moe_runs, moe_toks = {}, {}
    for name, m in (("single_device", None), ("sharded", mesh)):
        moe_runs[name], moe_toks[name] = _serve_mesh(
            moe_cfg, moe_params, moe_trace, m)
    msh = moe_runs["sharded"]

    return {
        "n_devices": len(jax.devices()),
        "single_device": sd,
        "sharded": sh,
        "token_exact": _token_exact(toks["sharded"],
                                    toks["single_device"]),
        "throughput_ratio": round(sh["tokens_per_s"]
                                  / sd["tokens_per_s"], 3),
        "kv_bytes_conserved": bool(
            sh["kv_bytes_per_device"] * sh["n_kv_shards"]
            == sh["kv_cache_bytes"]),
        "peak_pages_match_ledger": bool(
            sh["peak_pages_in_use_per_device"] == sh["peak_pages_in_use"]),
        "moe": {
            "single_device": moe_runs["single_device"],
            "sharded": msh,
            "token_exact": _token_exact(moe_toks["sharded"],
                                        moe_toks["single_device"]),
            "n_experts": moe_cfg.moe.n_experts,
            "expert_dispatch_conserved": bool(
                msh["experts_per_device"] * msh["n_expert_shards"]
                == moe_cfg.moe.n_experts),
        },
        "trace": {"n_requests": SH_N_REQUESTS,
                  "moe_n_requests": SH_MOE_N_REQUESTS,
                  "n_slots": N_SLOTS, "max_seq": MAX_SEQ,
                  "page_size": PAGE_SIZE,
                  "arrival_rate": ARRIVAL_RATE,
                  "prompt_lens": list(PROMPT_LENS),
                  "max_new": list(MAX_NEW)},
    }


def run_sharded_smoke() -> bool:
    """The ``--sharded`` CI lane: ``__main__`` forces
    ``SH_FORCED_DEVICES`` host devices BEFORE JAX initializes, then this
    asserts the real multi-device invariants the 1-device bench lane
    cannot exercise (4-way KV shards, 1 expert per device)."""
    sh = _sharded_report()
    n = SH_FORCED_DEVICES
    checks = {
        "dense_token_exact": sh["token_exact"] is True,
        "moe_token_exact": sh["moe"]["token_exact"] is True,
        "n_devices": sh["n_devices"] == n,
        "kv_shards": sh["sharded"]["n_kv_shards"] == n,
        "kv_bytes_conserved": sh["kv_bytes_conserved"],
        "peak_pages_match_ledger": sh["peak_pages_match_ledger"],
        "expert_shards": sh["moe"]["sharded"]["n_expert_shards"] == n,
        "expert_dispatch_conserved": sh["moe"]["expert_dispatch_conserved"],
        "pools_drained": (sh["sharded"]["pool_drained"]
                          and sh["moe"]["sharded"]["pool_drained"]),
    }
    for name, ok in checks.items():
        print(f"{'PASS' if ok else 'FAIL'}  sharded-smoke {name}")
    print(json.dumps({"throughput_ratio": sh["throughput_ratio"],
                      "kv_bytes_per_device":
                      sh["sharded"]["kv_bytes_per_device"],
                      "experts_per_device":
                      sh["moe"]["sharded"]["experts_per_device"]},
                     sort_keys=True))
    return all(checks.values())


def run():
    import jax
    from repro.models import transformer as T

    cfg, trace = _make_engine_inputs()
    params = T.init_params(jax.random.PRNGKey(0), cfg, max_seq=MAX_SEQ)

    serves = (
        ("fixed_slot", lambda: _serve_fixed(cfg, params, _clone(trace))),
        ("continuous", lambda: _serve_continuous(cfg, params, trace,
                                                 "paged")),
        ("continuous_contiguous",
         lambda: _serve_continuous(cfg, params, trace, "contiguous")),
    )
    rows = []
    out = {}
    tokens_seen = {}
    for name, serve in serves:
        serve()                            # warmup: populate jit caches
        tokens, wall, kv_stats, emitted = serve()
        tps = tokens / wall
        out[name] = {"useful_tokens": tokens, "wall_s": round(wall, 4),
                     "tokens_per_s": round(tps, 2), **kv_stats}
        tokens_seen[name] = emitted
        rows.append((f"serving_{name}", wall * 1e6 / max(tokens, 1),
                     {"tokens_per_s": round(tps, 2)}))

    out["speedup"] = round(out["continuous"]["tokens_per_s"]
                           / out["fixed_slot"]["tokens_per_s"], 3)
    paged_toks = tokens_seen["continuous"]
    contig_toks = tokens_seen["continuous_contiguous"]
    out["paged_token_exact"] = (
        len(paged_toks) == len(contig_toks)
        and all(np.array_equal(a, b)
                for a, b in zip(paged_toks, contig_toks)))
    out["paged_vs_contiguous_kv_bytes"] = round(
        out["continuous"]["kv_cache_bytes"]
        / out["continuous_contiguous"]["kv_cache_bytes"], 4)
    out["trace"] = {"n_requests": N_REQUESTS, "n_slots": N_SLOTS,
                    "max_seq": MAX_SEQ,
                    "arrival_rate": ARRIVAL_RATE,
                    "prompt_lens": list(PROMPT_LENS),
                    "max_new": list(MAX_NEW),
                    "page_size": PAGE_SIZE}
    cw = _contact_window_report(cfg, params, trace, tokens_seen["continuous"])
    cw["overlap"] = _overlap_report(cfg, params, trace,
                                    tokens_seen["continuous"])
    out["contact_window"] = cw
    out["chunked_prefill"] = _chunked_prefill_report(cfg, params)
    out["shared_prefix"] = _shared_prefix_report(cfg, params)
    out["fault_replay"] = _fault_replay_report(cfg, params)
    out["speculative"] = _speculative_report(cfg, params)
    out["constellation"] = _constellation_report(cfg, params)
    out["sharded"] = _sharded_report()
    out["bench_version"] = BENCH_VERSION
    rows.append(("serving_contact_window_preemptive",
                 cw["preemptive"]["wall_s"] * 1e6
                 / max(cw["preemptive"]["useful_tokens"], 1),
                 {"goodput_ratio": cw["goodput_ratio"],
                  "n_preemptions": cw["preemptive"]["n_preemptions"],
                  "token_exact": cw["token_exact_vs_uninterrupted"]}))
    ov = cw["overlap"]
    rows.append(("serving_contact_window_overlap",
                 ov["overlapped"]["wall_s"] * 1e6
                 / max(ov["overlapped"]["useful_tokens"], 1),
                 {"goodput_ratio_vs_stop_the_world":
                  ov["goodput_ratio_vs_stop_the_world"],
                  "n_delta_spills": ov["overlapped"]["n_delta_spills"],
                  "delta_spill_bytes": ov["delta_spill_bytes"],
                  "full_spill_bytes_equiv": ov["full_spill_bytes_equiv"],
                  "token_exact": ov["token_exact_vs_uninterrupted"]}))
    cp = out["chunked_prefill"]
    rows.append(("serving_chunked_prefill_tick_p99",
                 cp["chunked"]["tick_latency_p99_s"] * 1e6,
                 {"tick_p99_ratio": cp["tick_p99_ratio"],
                  "monolithic_p99_us": round(
                      cp["monolithic"]["tick_latency_p99_s"] * 1e6, 1),
                  "token_exact": cp["token_exact"],
                  "ttft_mean_steps": cp["chunked"]["ttft_mean_steps"]}))
    fr = out["fault_replay"]
    rows.append(("serving_fault_replay",
                 fr["faulted"]["wall_s"] * 1e6
                 / max(fr["faulted"]["n_answers"], 1),
                 {"token_exact": fr["token_exact_vs_fault_free"],
                  "n_corruptions_detected":
                  fr["faulted"]["n_corruptions_detected"],
                  "n_corruptions_injected":
                  fr["faulted"]["injected"]["n_corruptions_injected"],
                  "n_reboots": fr["faulted"]["n_reboots"],
                  "goodput_efficiency":
                  fr["faulted"]["goodput_efficiency"]}))
    sp = out["shared_prefix"]
    rows.append(("serving_shared_prefix",
                 sp["shared"]["wall_s"] * 1e6
                 / max(sp["shared"]["useful_tokens"], 1),
                 {"prefill_tokens_ratio": sp["prefill_tokens_ratio"],
                  "kv_peak_bytes_ratio": sp["kv_peak_bytes_ratio"],
                  "prefix_hits": sp["shared"]["prefix_hits"],
                  "cow_page_copies": sp["shared"]["cow_page_copies"],
                  "token_exact": sp["token_exact"]}))
    sd = out["speculative"]
    rows.append(("serving_speculative",
                 sd["verify"]["speculative"]["wall_s"] * 1e6
                 / max(sd["verify"]["speculative"]["useful_tokens"], 1),
                 {"throughput_ratio": sd["verify"]["throughput_ratio"],
                  "token_exact": sd["verify"]["token_exact"],
                  "accepted": sd["verify"]["speculative"]["accepted"],
                  "cascade_token_exact":
                  sd["cascade"]["token_exact_vs_raw"],
                  "bytes_per_escalation_raw":
                  sd["cascade"]["raw"]["bytes_per_escalation"],
                  "bytes_per_escalation_spec":
                  sd["cascade"]["speculative"]["bytes_per_escalation"]}))
    cn = out["constellation"]
    rows.append(("serving_constellation",
                 cn["pooled"]["wall_s"] * 1e6
                 / max(cn["pooled"]["delivered_tokens"], 1),
                 {"goodput_ratio": cn["goodput_ratio"],
                  "n_handovers": cn["pooled"]["n_handovers"],
                  "token_exact": cn["token_exact_vs_solo"],
                  "independent_goodput":
                  cn["independent_pairs"]["goodput_tokens_per_tick"],
                  "within_energy_budget":
                  cn["pooled"]["within_energy_budget"]}))
    shd = out["sharded"]
    rows.append(("serving_sharded",
                 shd["sharded"]["wall_s"] * 1e6
                 / max(shd["sharded"]["useful_tokens"], 1),
                 {"n_devices": shd["n_devices"],
                  "n_kv_shards": shd["sharded"]["n_kv_shards"],
                  "throughput_ratio": shd["throughput_ratio"],
                  "token_exact": shd["token_exact"],
                  "moe_expert_shards":
                  shd["moe"]["sharded"]["n_expert_shards"],
                  "moe_token_exact": shd["moe"]["token_exact"]}))
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_serving.json"), "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    rows.append(("serving_speedup", 0.0, {"speedup": out["speedup"]}))
    return rows


if __name__ == "__main__":
    import sys

    if len(sys.argv) > 1 and sys.argv[1] == "--sharded":
        # must land in XLA_FLAGS before anything imports jax (the
        # module itself only imports numpy at top level, so this is
        # still early enough here)
        flags = os.environ.get("XLA_FLAGS", "")
        if "--xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{SH_FORCED_DEVICES}").strip()
        ok = run_sharded_smoke()
        print(f"sharded smoke {'ok' if ok else 'FAILED'}")
        sys.exit(0 if ok else 1)
    if len(sys.argv) > 1 and sys.argv[1] == "--chaos-constellation":
        seeds = [int(s) for s in sys.argv[2:]] or [CN_FAULT_SEED]
        failures = run_constellation_chaos(seeds)
        if failures:
            print(f"constellation chaos sweep FAILED: {failures}")
            sys.exit(1)
        print(f"constellation chaos sweep ok across seeds {seeds}")
        sys.exit(0)
    if len(sys.argv) > 1 and sys.argv[1] == "--chaos":
        seeds = [int(s) for s in sys.argv[2:]] or [0, 1, 2, 3, 4]
        failures = run_chaos(seeds)
        if failures:
            print(f"chaos sweep FAILED: {failures}")
            sys.exit(1)
        print(f"chaos sweep ok across seeds {seeds}")
        sys.exit(0)
    for name, us, derived in run():
        print(f"{name},{us:.1f},{json.dumps(derived, sort_keys=True)}")
