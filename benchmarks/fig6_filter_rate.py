"""Paper Figure 6: filter rate of redundant data in orbit on DOTA.

The paper reports ~90% of tiles filtered on dataset version 1 and ~40%
on version 2 after onboard splitting + redundancy filtering.  We run the
same pipeline (split -> cloud/redundancy filter) over the synthetic EO
generator's two version regimes."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.filtering import filter_tiles
from repro.data import eo

PAPER = {"v1": 0.90, "v2": 0.40}


def run(n_tiles: int = 600):
    rows = []
    for name, cfg in (("v1", eo.V1), ("v2", eo.V2)):
        tiles, labels, cloudy = eo.make_tiles(n_tiles, cfg)
        t_j = jnp.asarray(tiles)
        f = jax.jit(lambda x: filter_tiles(x)[1]["filter_rate"])
        rate = float(f(t_j))                    # compile
        t0 = time.perf_counter()
        for _ in range(3):
            rate = float(f(t_j))
        us = (time.perf_counter() - t0) / 3 * 1e6
        rows.append((f"fig6_filter_rate_{name}", us, {
            "filter_rate": round(rate, 3),
            "paper": PAPER[name],
            "abs_gap": round(abs(rate - PAPER[name]), 3),
            "n_tiles": n_tiles,
        }))
    return rows
