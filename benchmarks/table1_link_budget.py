"""Paper Table 1: satellite platform link budget.

Derived benchmark: for each satellite's link spec, the time to downlink
one orbit of raw imagery vs the collaborative system's payload, against
the available contact time — shows WHY bent-pipe breaks (paper §II) and
what the 90% reduction buys."""
from __future__ import annotations

import time

from repro.core.link import ContactSchedule, LinkModel

SATS = {
    "baoyun": LinkModel(uplink_mbps=1.0, downlink_mbps=40.0),
    "chuangxingleishen": LinkModel(uplink_mbps=1.0, downlink_mbps=40.0),
}

ORBIT_RAW_BYTES = 2.0e9          # ~2 GB of imagery per orbit (ZY-3-like)
REDUCTION = 0.90                 # the system's measured reduction


def run():
    rows = []
    for name, link in SATS.items():
        sched = ContactSchedule(link=link, seed=7)
        t0 = time.perf_counter()
        day_cap = sched.downlink_capacity_bytes(86_400.0)
        t_raw = link.downlink_time_s(ORBIT_RAW_BYTES)
        t_collab = link.downlink_time_s(ORBIT_RAW_BYTES * (1 - REDUCTION))
        us = (time.perf_counter() - t0) * 1e6
        orbits_per_day = 86_400.0 / sched.link.orbital_period_s
        rows.append((f"table1_link_budget_{name}", us, {
            "orbital_period_s": round(link.orbital_period_s, 1),
            "orbits_per_day": round(orbits_per_day, 2),
            "daily_contact_capacity_gb": round(day_cap / 1e9, 2),
            "raw_downlink_s_per_orbit": round(t_raw, 1),
            "collab_downlink_s_per_orbit": round(t_collab, 1),
            "raw_fits_in_contacts": bool(
                t_raw * orbits_per_day
                <= sched.contacts_per_day * sched.contact_duration_s),
            "collab_fits_in_contacts": bool(
                t_collab * orbits_per_day
                <= sched.contacts_per_day * sched.contact_duration_s),
        }))
    return rows
