"""Paper Tables 2-3: onboard energy distribution.

Claims: payloads ~53% of total; the compute payload (Raspberry Pi) ~33%
of payload energy and ~17% of total onboard energy.  The model carries
the paper's measured watt values; this benchmark checks our accounting
reproduces the published shares and derives an activity-based figure for
a representative duty cycle."""
from __future__ import annotations

import time

from repro.core.energy import EnergyModel

PAPER = {"compute_of_total": 0.17, "payload_of_total": 0.53,
         "compute_of_payload": 0.33}


def run():
    em = EnergyModel()
    t0 = time.perf_counter()
    shares = {
        "compute_of_total": em.compute_share_of_total(),
        "payload_of_total": em.payload_share_of_total(),
        "compute_of_payload": em.compute_share_of_payload(),
    }
    # activity-based: one orbit (95 min) with 1000 tile inferences and a
    # single 480 s downlink pass
    e_inf = em.inference_energy_j(1000, 0.35)
    e_comm = em.comm_energy_j(480.0)
    e_total = em.energy_budget_j(95 * 60.0)
    us = (time.perf_counter() - t0) * 1e6
    return [("table23_energy", us, {
        **{k: round(v, 3) for k, v in shares.items()},
        **{f"paper_{k}": v for k, v in PAPER.items()},
        "orbit_inference_j": round(e_inf, 1),
        "orbit_comm_j": round(e_comm, 1),
        "orbit_budget_j": round(e_total, 1),
        "duty_compute_fraction": round(e_inf / e_total, 3),
    })]
