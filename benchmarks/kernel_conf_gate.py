"""Confidence-gate kernel benchmark: the paper's gating primitive at LM
vocab scale.  On CPU we time the 3-pass jnp reference (softmax -> top2 ->
entropy) vs the single-pass online algorithm expressed in jnp (the same
math the Pallas kernel executes per VMEM tile), and report the analytic
HBM-byte ratio (3 passes -> 1 pass over (B, V) logits)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.ref import confidence_gate_ref


def _online_gate(logits, block=4096):
    """Single-pass online computation (jnp mirror of the Pallas kernel)."""
    B, V = logits.shape
    nb = -(-V // block)
    pad = nb * block - V
    x = jnp.pad(logits, ((0, 0), (0, pad)), constant_values=-1e30)
    xb = x.reshape(B, nb, block).swapaxes(0, 1)

    def body(carry, xblk):
        m1, m2, l, sx = carry
        bm1 = jnp.max(xblk, -1)
        bm2 = jnp.sort(xblk, -1)[:, -2]
        m1n = jnp.maximum(m1, bm1)
        m2n = jnp.maximum(jnp.maximum(m2, bm2), jnp.minimum(m1, bm1))
        corr = jnp.exp(m1 - m1n)
        l = l * corr + jnp.sum(jnp.exp(xblk - m1n[:, None]), -1)
        sx = sx * corr + jnp.sum(
            jnp.where(xblk > -1e29, xblk, 0.0)
            * jnp.exp(xblk - m1n[:, None]), -1)
        return (m1n, m2n, l, sx), None

    init = (jnp.full((B,), -1e30), jnp.full((B,), -1e30),
            jnp.zeros((B,)), jnp.zeros((B,)))
    (m1, m2, l, sx), _ = jax.lax.scan(body, init, xb)
    lse = m1 + jnp.log(jnp.maximum(l, 1e-30))
    return {"max_prob": jnp.exp(m1 - lse), "entropy": lse - sx / l,
            "margin": jnp.exp(m1 - lse) - jnp.exp(m2 - lse)}


def _time(f, *args, reps=5):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    for (B, V) in [(64, 49152), (32, 151936)]:
        logits = jax.random.normal(jax.random.PRNGKey(0), (B, V))
        ref_j = jax.jit(confidence_gate_ref)
        onl_j = jax.jit(_online_gate)
        t_ref = _time(ref_j, logits)
        t_onl = _time(onl_j, logits)
        bytes_tile = B * V * 4
        rows.append((f"conf_gate_B{B}_V{V}", t_onl, {
            "us_3pass_ref": round(t_ref, 1),
            "us_online": round(t_onl, 1),
            "hbm_bytes_3pass": 3 * bytes_tile,
            "hbm_bytes_fused": bytes_tile,
            "hbm_ratio": 3.0,
        }))
    return rows
