"""Paper Figure 7: accuracy of in-orbit vs collaborative inference.

The paper reports +44% and +52% relative accuracy from collaborative
inference over in-orbit-only on two dataset versions (avg ~+50%), with
~90% of data NOT downlinked.  We train the onboard/ground tier pair on
synthetic EO tiles at two difficulty regimes and run the cascade with a
threshold calibrated to a ~35-45% escalation budget."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import classifier as CL
from repro.core.cascade import CascadeConfig, CollaborativeEngine
from repro.core.gating import ConfidenceGate, calibrate_threshold
from repro.data import eo

PAPER = {"v1": 0.44, "v2": 0.52}
# escalation budget (the deployment knob the paper tunes against its
# downlink budget) per dataset version
BUDGET = {"v1": 0.45, "v2": 0.26}
REGIMES = {
    "v1": eo.EOConfig(cloud_fraction=0.0, dup_fraction=0.0, contrast=0.42,
                      noise=0.26, seed=21),
    "v2": eo.EOConfig(cloud_fraction=0.0, dup_fraction=0.0, contrast=0.58,
                      noise=0.20, seed=22),
}


def run(n_train: int = 2500, n_test: int = 500):
    rows = []
    for name, cfg in REGIMES.items():
        tr_t, tr_l, _ = eo.make_tiles(n_train, cfg)
        te_t, te_l, _ = eo.make_tiles(
            n_test, eo.EOConfig(**{**cfg.__dict__, "seed": cfg.seed + 100}))
        keep = te_l >= 0
        tiles, labels = te_t[keep], te_l[keep]

        onboard, _ = CL.train_classifier(CL.ONBOARD, tr_t, tr_l, steps=350)
        ground, _ = CL.train_classifier(CL.GROUND, tr_t, tr_l, steps=700)

        onboard_fn = lambda b: CL.apply_classifier(onboard, CL.ONBOARD,
                                                   jnp.asarray(b))
        ground_fn = lambda b: CL.apply_classifier(ground, CL.GROUND,
                                                  jnp.asarray(b))
        # calibrate the threshold to an escalation budget (deployment knob)
        probe = np.asarray(
            ConfidenceGate("max_prob", 1.1).decide(
                jnp.asarray(onboard_fn(tiles)))["confidence"])
        thr = calibrate_threshold(probe, np.ones_like(probe, bool),
                                  BUDGET[name])

        eng = CollaborativeEngine(onboard_fn, ground_fn, CascadeConfig(
            gate=ConfidenceGate("max_prob", thr), item_dtype_bytes=4))
        t0 = time.perf_counter()
        collab = eng.run(tiles, item_shape=tiles.shape[1:])
        us = (time.perf_counter() - t0) * 1e6
        inorbit = eng.run(tiles, item_shape=tiles.shape[1:],
                          ground_available=False)

        acc_c = float(np.mean(collab.predictions == labels))
        acc_o = float(np.mean(inorbit.predictions == labels))
        rel = (acc_c - acc_o) / max(acc_o, 1e-9)
        s = collab.ledger.summary()
        rows.append((f"fig7_accuracy_{name}", us, {
            "acc_inorbit": round(acc_o, 3),
            "acc_collaborative": round(acc_c, 3),
            "relative_gain": round(rel, 3),
            "paper_relative_gain": PAPER[name],
            "escalation_rate": round(s["escalation_rate"], 3),
            "threshold": round(thr, 3),
        }))
    return rows
