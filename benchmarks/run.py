"""Benchmark harness — one benchmark per paper table/figure (+ kernel
microbenchmarks).  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig6,fig7,...]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

BENCHES = ("fig6_filter_rate", "fig7_accuracy", "table1_link_budget",
           "table23_energy", "data_reduction", "kernel_conf_gate",
           "serving_throughput")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark module names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    import importlib
    print("name,us_per_call,derived")
    failures = []
    for mod_name in BENCHES:
        if only and mod_name not in only:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
            rows = mod.run()
        except Exception as e:      # pragma: no cover
            import traceback
            traceback.print_exc()
            failures.append(mod_name)
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{json.dumps(derived, sort_keys=True)}")
        print(f"# {mod_name} wall {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
