"""Paper headline: 90% reduction in data returned by the satellite.

End-to-end pipeline over a cloudy scene (v1 regime): split -> filter ->
onboard inference -> confidence gate -> downlink (results | escalated
raw).  Reduction = 1 - bytes_downlinked / bytes_bent_pipe."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import classifier as CL
from repro.core.cascade import CascadeConfig, CollaborativeEngine
from repro.core.filtering import filter_tiles
from repro.core.gating import ConfidenceGate, calibrate_threshold
from repro.data import eo

PAPER = 0.90


def run(n_tiles: int = 500):
    # train a quick tier pair on clear tiles from the SAME distribution
    # the cloudy scene draws from (V1 defaults: contrast 0.9, noise 0.22)
    tcfg = eo.EOConfig(cloud_fraction=0.0, dup_fraction=0.0, contrast=0.9,
                       noise=0.22, seed=31)
    tr_t, tr_l, _ = eo.make_tiles(1500, tcfg)
    onboard, _ = CL.train_classifier(CL.ONBOARD, tr_t, tr_l, steps=250)
    ground, _ = CL.train_classifier(CL.GROUND, tr_t, tr_l, steps=400)

    tiles, labels, cloudy = eo.make_tiles(n_tiles, eo.V1)
    t0 = time.perf_counter()
    keep, fstats = filter_tiles(jnp.asarray(tiles))
    keep = np.asarray(keep)
    survivors = tiles[keep]
    onboard_fn = lambda b: CL.apply_classifier(onboard, CL.ONBOARD,
                                               jnp.asarray(b))
    # calibrate the gate to a ~35% escalation budget on the survivors
    probe = np.asarray(ConfidenceGate("max_prob", 1.1).decide(
        jnp.asarray(onboard_fn(survivors)))["confidence"])
    thr = calibrate_threshold(probe, np.ones_like(probe, bool), 0.35)
    eng = CollaborativeEngine(
        onboard_fn,
        lambda b: CL.apply_classifier(ground, CL.GROUND, jnp.asarray(b)),
        CascadeConfig(gate=ConfidenceGate("max_prob", thr),
                      item_dtype_bytes=4))
    res = eng.run(survivors, item_shape=survivors.shape[1:])
    us = (time.perf_counter() - t0) * 1e6

    bent_pipe = float(tiles.nbytes)
    downlinked = res.ledger.get("bytes_downlinked")
    reduction = 1.0 - downlinked / bent_pipe
    return [("data_reduction_e2e", us, {
        "bytes_bent_pipe": int(bent_pipe),
        "bytes_downlinked": int(downlinked),
        "reduction": round(reduction, 3),
        "paper": PAPER,
        "filter_rate": round(float(fstats["filter_rate"]), 3),
        "escalation_rate": round(
            res.ledger.summary().get("escalation_rate", 0.0), 3),
    })]
