"""Dev helper: run a reduced forward + loss + decode step for every arch."""
import sys

import jax
import jax.numpy as jnp

from repro.config import ARCH_IDS, get_reduced_config
from repro.models import transformer as T

ok = True
for arch in ARCH_IDS:
    cfg = get_reduced_config(arch)
    try:
        key = jax.random.PRNGKey(0)
        B, S = 2, 64
        params = T.init_params(key, cfg, max_seq=S)
        batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.ones((B, cfg.n_patches, cfg.d_model), jnp.bfloat16) * 0.01
        if cfg.family == "audio":
            batch["audio_frames"] = jnp.ones((B, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16) * 0.01
        loss, metrics = T.loss_fn(params, cfg, batch)
        n = cfg.param_count()
        assert jnp.isfinite(loss), f"{arch}: loss not finite"
        # decode one step
        cache = T.init_cache(cfg, B, 128)
        logits, cache = T.decode_step(params, cfg, cache,
                                      batch["tokens"][:, :1], jnp.int32(0))
        assert logits.shape == (B, 1, cfg.vocab_size), logits.shape
        assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: decode NaN"
        print(f"OK   {arch:24s} loss={float(loss):8.4f} params={n:,}")
    except Exception as e:
        ok = False
        import traceback
        print(f"FAIL {arch}: {type(e).__name__}: {e}")
        traceback.print_exc(limit=6)
sys.exit(0 if ok else 1)
