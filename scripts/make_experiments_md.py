"""Generate EXPERIMENTS.md from results/ artifacts (dry-run sweeps,
benchmark CSV, perf iterations)."""
import glob
import json
import os
import sys

sys.path.insert(0, "src")
from repro.analysis.roofline import load_rows, to_markdown  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load_results(d):
    out = []
    for f in sorted(glob.glob(os.path.join(ROOT, d, "*.json"))):
        out.append(json.load(open(f)))
    return out


def gb(x):
    return f"{x/1e9:.2f}"


def dryrun_table(results):
    rows = ["| arch | shape | mesh | compile s | flops/dev | bytes/dev "
            "| link bytes/dev | collectives (ar/ag/rs/a2a/cp) |",
            "|---|---|---|---|---|---|---|---|"]
    for r in results:
        if r.get("skipped"):
            rows.append(f"| {r['arch']} | {r['shape']} | — | SKIP "
                        f"| — | — | — | {r['reason']} |")
            continue
        c = r["collectives"]
        cc = "/".join(str(c[k]["count"]) for k in
                      ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute"))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compile_s']} | {r['flops_per_device']:.2e} "
            f"| {r['bytes_per_device']:.2e} "
            f"| {c['total_link_bytes']:.2e} | {cc} |")
    return "\n".join(rows)


def bench_section(path):
    if not os.path.exists(path):
        return "(benchmarks not yet captured — see bench_output.txt)"
    lines = open(path).read().strip().splitlines()
    out = ["```csv"] + lines + ["```"]
    return "\n".join(out)


def perf_section():
    """Hand-maintained perf log entries + measured artifacts."""
    entries = []
    for f in sorted(glob.glob(os.path.join(ROOT, "results/perf/*.json"))):
        r = json.load(open(f))
        if "error" in r:
            continue
        c = r["collectives"]["total_link_bytes"]
        entries.append(
            f"| {os.path.basename(f)[:-5]} | {r['arch']} | {r['shape']} "
            f"| {r.get('sharding','baseline')}/{r['moe_dispatch']} "
            f"| {r['flops_per_device']:.2e} | {r['bytes_per_device']:.2e} "
            f"| {c:.2e} |")
    hdr = ["| run | arch | shape | sharding/dispatch | flops/dev "
           "| bytes/dev | link bytes/dev |",
           "|---|---|---|---|---|---|---|"]
    return "\n".join(hdr + entries)


def main():
    single = load_results("results/v2/single")
    multi = load_results("results/v2b_multi")
    if not multi:
        multi = load_results("results/v2/multi")
    roof = load_rows(os.path.join(ROOT, "results/v2/single"))

    md = open(os.path.join(ROOT, "docs/EXPERIMENTS.header.md")).read()
    md += "\n\n## §Dry-run — single pod (16x16 = 256 chips)\n\n"
    md += dryrun_table(single)
    md += "\n\n## §Dry-run — multi-pod (2x16x16 = 512 chips)\n\n"
    md += dryrun_table(multi)
    md += "\n\n## §Roofline — single pod, per (arch x shape)\n\n"
    md += to_markdown(roof)
    md += "\n\n## §Perf — measured iterations (see log below)\n\n"
    md += perf_section()
    if os.path.exists(os.path.join(ROOT, "docs/EXPERIMENTS.perf.md")):
        md += "\n\n" + open(os.path.join(ROOT,
                                         "docs/EXPERIMENTS.perf.md")).read()
    if os.path.exists(os.path.join(ROOT, "docs/EXPERIMENTS.claims.md")):
        md += "\n\n" + open(os.path.join(
            ROOT, "docs/EXPERIMENTS.claims.md")).read()
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write(md)
    print(f"wrote EXPERIMENTS.md ({len(md)} chars, "
          f"{len(single)}+{len(multi)} dry-runs, {len(roof)} roofline rows)")


if __name__ == "__main__":
    main()
