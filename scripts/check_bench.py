#!/usr/bin/env python
"""Versioned serving-benchmark gate: every CI assertion on
``BENCH_serving.json``, checked-in and runnable locally.

    PYTHONPATH=src python -m benchmarks.serving_throughput
    python scripts/check_bench.py BENCH_serving.json

The serving-bench CI job runs exactly this (``.github/workflows/
ci.yml``), so the gates are reviewable in diffs instead of living in a
workflow heredoc.  ``GATE_VERSION`` pairs with the benchmark's
``bench_version``: bump both when gated keys change, so a stale
BENCH_serving.json fails loudly instead of silently passing old gates.

Gates:
  * throughput — paged continuous >= 1.5x fixed-slot tokens/s, paged
    token-exact with the contiguous layout, paged KV bytes (allocated
    AND measured peak) strictly below the contiguous reservation;
  * contact_window — preempt-and-resume token-exact with the
    uninterrupted run, goodput >= the abort-and-restart baseline,
    preemptions actually observed (resumes balanced), pools drained;
  * contact_window.overlap — overlapped goodput >= stop-the-world
    goodput on the SAME window schedule, decode really ran during
    passes, delta spills observed with delta bytes < full-spill bytes,
    both replays token-exact, pools drained, spill store empty;
  * chunked_prefill — the unified token-budget step on the heavy-tail
    prompt mix: chunked token-exact with the monolithic (unbounded)
    run, chunked p99 tick latency STRICTLY below monolithic on the
    same trace, per-tick prefill tokens bounded by the budget (and the
    monolithic run genuinely unbounded — the comparator is real),
    pools drained;
  * shared_prefix — refcounted prefix sharing + copy-on-write on the
    header-heavy trace: shared replay token-exact with the unshared
    one, peak KV pool bytes AND total prefill tokens STRICTLY below
    the unshared replay's, prefix hits really observed, and the pool
    and refcounts fully drained once the index is cleared;
  * fault_replay — the all-faults-armed replay (frame loss + bit-flip
    corruption + early-LOS truncation + spill corruption + one
    scheduled crash): final AND satellite tokens identical to the
    fault-free run, every injected corruption detected with zero
    silent acceptances, retransmitted/lost bytes metered, the framed
    byte ledger conserved, goodput efficiency bounded below by the
    injected loss, the crash survived exactly once via
    checkpoint/restore, pools and spill store drained after;
  * speculative — draft–verify decoding in the unified step: the
    self-draft verify replay token-exact with plain decode at >= its
    tokens/s in strictly fewer engine ticks, with every draft accepted
    through real verify passes; the cascade replay token-exact with
    raw escalation while shipping STRICTLY fewer bytes per escalation
    and answering on the ground tier in strictly fewer ticks, with the
    draft/raw byte split metered in the ledger and pools drained;
  * constellation — K-satellite contact planning with token-exact
    inter-satellite handover vs the K-independent-pairs comparator on
    the same window sets: pooled goodput >= independent goodput at
    equal energy/byte budget (both within the per-satellite bus cap,
    downlink payload bytes no greater than the comparator's), handovers
    really happened, both replays token-exact with a solo run of the
    same requests, all answers delivered, every pool, spill store and
    lane drained;
  * sharded — the mesh-sharded engine (tensor-parallel attention +
    per-device KV page pools, expert-parallel MoE dispatch) vs the
    single-device engine on the SAME traces: both the dense and MoE
    replays token-exact, sharded tokens/s >= SHARDED_MIN_RATIO x the
    single-device run's at equal batch, per-device KV bytes times the
    shard count reconstructing the global pool exactly, the per-device
    page ledger identical to the global one (page axes are never cut),
    per-device expert dispatch conserving the expert count, and both
    pools drained.  On the default 1-device lane the mesh is trivial
    (an A/A parity check); the ``sharded-smoke`` CI job reruns the
    section 4-way via ``--sharded`` with its own inline assertions.

Each gate prints PASS/FAIL; the exit code is non-zero if any failed.
"""
from __future__ import annotations

import json
import sys

GATE_VERSION = 8

# sharded-vs-single throughput floor: parity with noise margin (the
# bench times best-of-N sub-second replays).  On a real multi-device
# mesh the sharded run should win outright; on the 1-device bench
# lane the mesh is trivial and the honest expectation is parity, so
# the gate guards against the mesh machinery REGRESSING throughput
# rather than demanding a speedup the hardware can't show.
SHARDED_MIN_RATIO = 0.9


class Gates:
    def __init__(self) -> None:
        self.failures = 0

    def check(self, name: str, ok: bool, detail="") -> None:
        status = "PASS" if ok else "FAIL"
        suffix = f"  [{detail}]" if detail != "" else ""
        print(f"{status}  {name}{suffix}")
        if not ok:
            self.failures += 1


def check_version(g: Gates, bench: dict) -> None:
    got = bench.get("bench_version")
    g.check("bench_version matches gate version", got == GATE_VERSION,
            f"bench={got} gates={GATE_VERSION}")


def check_throughput(g: Gates, bench: dict) -> None:
    paged = bench["continuous"]
    contig = bench["continuous_contiguous"]
    g.check("paged continuous >= 1.5x fixed-slot tokens/s",
            bench["speedup"] >= 1.5, f"speedup={bench['speedup']}")
    g.check("paged token-exact with contiguous layout",
            bench["paged_token_exact"] is True)
    g.check("continuous run uses the paged layout",
            paged["kv_layout"] == "paged")
    # the allocated pool is smaller than the contiguous layout...
    g.check("paged KV bytes < contiguous KV bytes",
            paged["kv_cache_bytes"] < contig["kv_cache_bytes"],
            f"{paged['kv_cache_bytes']} vs {contig['kv_cache_bytes']}")
    # ...AND measured peak usage stays under the contiguous reservation
    # (catches page leaks the static pool size hides)
    peak_positions = paged["peak_pages_in_use"] * paged["page_size"]
    contig_positions = (bench["trace"]["n_slots"]
                        * bench["trace"]["max_seq"])
    g.check("peak paged positions < contiguous reservation",
            peak_positions < contig_positions,
            f"{peak_positions} vs {contig_positions}")
    g.check("page-pool utilization in (0, 1]",
            0.0 < paged["page_pool_utilization"] <= 1.0,
            f"{paged['page_pool_utilization']}")


def check_contact_window(g: Gates, cw: dict) -> None:
    pre, res = cw["preemptive"], cw["restart"]
    g.check("preemptive replay token-exact vs uninterrupted",
            cw["token_exact_vs_uninterrupted"] is True)
    # windows really interrupted in-flight sequences
    g.check("preemptions observed", pre["n_preemptions"] > 0,
            f"n={pre['n_preemptions']}")
    g.check("resumes balance preemptions",
            pre["n_resumes"] == pre["n_preemptions"],
            f"{pre['n_resumes']} vs {pre['n_preemptions']}")
    # resume beats redoing the work on the same schedule
    g.check("preemptive goodput >= restart goodput",
            cw["goodput_ratio"] >= 1.0, f"ratio={cw['goodput_ratio']}")
    g.check("useful tokens equal across replays",
            pre["useful_tokens"] == res["useful_tokens"],
            f"{pre['useful_tokens']} vs {res['useful_tokens']}")
    g.check("preemptive pool drained", pre["pool_drained"] is True)
    g.check("restart pool drained", res["pool_drained"] is True)


def check_overlap(g: Gates, ov: dict) -> None:
    o, stw = ov["overlapped"], ov["stop_the_world"]
    g.check("overlapped replay token-exact vs uninterrupted",
            ov["token_exact_vs_uninterrupted"] is True)
    g.check("stop-the-world replay token-exact vs uninterrupted",
            ov["stop_the_world_token_exact"] is True)
    # the tentpole: transmit/compute overlap beats holding the compute
    # for the whole pass, on the SAME window schedule
    g.check("overlapped goodput >= stop-the-world goodput",
            ov["goodput_ratio_vs_stop_the_world"] >= 1.0,
            f"ratio={ov['goodput_ratio_vs_stop_the_world']}")
    g.check("decode ticks observed inside windows",
            o["decode_steps_in_window"] > 0,
            f"n={o['decode_steps_in_window']}")
    g.check("stop-the-world never decodes inside windows",
            stw["decode_steps_in_window"] == 0,
            f"n={stw['decode_steps_in_window']}")
    # the KV-delta spill format: re-preempted sequences ship only the
    # pages dirtied since their last spill
    g.check("delta spills observed", o["n_delta_spills"] > 0,
            f"n={o['n_delta_spills']}")
    g.check("delta-spill bytes < full-spill bytes",
            ov["delta_spill_bytes"] < ov["full_spill_bytes_equiv"],
            f"{ov['delta_spill_bytes']} vs {ov['full_spill_bytes_equiv']}")
    g.check("overlapped resumes balance preemptions",
            o["n_resumes"] == o["n_preemptions"],
            f"{o['n_resumes']} vs {o['n_preemptions']}")
    g.check("overlapped pool drained", o["pool_drained"] is True)
    g.check("stop-the-world pool drained", stw["pool_drained"] is True)
    g.check("spill store empty after drain",
            o["spill_store_empty"] is True)


def check_chunked_prefill(g: Gates, cp: dict) -> None:
    ch, mono = cp["chunked"], cp["monolithic"]
    budget = cp["trace"]["prefill_budget_tokens"]
    g.check("chunked run token-exact with monolithic prefill",
            cp["token_exact"] is True)
    # the tentpole: bounding every tick's prefill tokens bounds the
    # tail tick latency — the p99 tick must be strictly faster than the
    # monolithic run's on the SAME heavy-tail trace
    g.check("chunked tick p99 < monolithic tick p99",
            ch["tick_latency_p99_s"] < mono["tick_latency_p99_s"],
            f"{ch['tick_latency_p99_s']}s vs {mono['tick_latency_p99_s']}s")
    g.check("per-tick prefill tokens bounded by the budget",
            0 < ch["max_prefill_tokens_per_tick"] <= budget,
            f"{ch['max_prefill_tokens_per_tick']} vs budget {budget}")
    # the comparator really is monolithic: some tick swallowed a whole
    # heavy prompt in one chunk
    g.check("monolithic run exceeded the budget in one tick",
            mono["max_prefill_tokens_per_tick"] > budget,
            f"{mono['max_prefill_tokens_per_tick']} vs budget {budget}")
    g.check("chunked pool drained", ch["pool_drained"] is True)
    g.check("monolithic pool drained", mono["pool_drained"] is True)


def check_shared_prefix(g: Gates, sp: dict) -> None:
    sh, un = sp["shared"], sp["unshared"]
    g.check("shared replay token-exact with unshared",
            sp["token_exact"] is True)
    # the tentpole: attaching cached header pages by reference must
    # shrink BOTH the memory footprint and the recomputed prompt work
    g.check("shared peak KV bytes < unshared peak KV bytes",
            sh["kv_peak_bytes"] < un["kv_peak_bytes"],
            f"{sh['kv_peak_bytes']} vs {un['kv_peak_bytes']}")
    g.check("shared prefill tokens < unshared prefill tokens",
            sh["prefill_tokens_total"] < un["prefill_tokens_total"],
            f"{sh['prefill_tokens_total']} vs {un['prefill_tokens_total']}")
    # the sharing machinery really engaged (not a vacuous comparison)
    g.check("prefix-cache hits observed", sh["prefix_hits"] > 0,
            f"n={sh['prefix_hits']}")
    g.check("prompt positions skipped by reference",
            sh["prefill_positions_skipped"] > 0,
            f"n={sh['prefill_positions_skipped']}")
    # end of life: clearing the index must return every shared page —
    # refcounts hit zero exactly once per page or the pool can't drain
    g.check("shared pool + refcounts drained after index clear",
            sh["pool_drained"] is True,
            f"live_refs_before_clear={sh['live_refs_before_clear']}")
    g.check("unshared pool drained", un["pool_drained"] is True)


def check_fault_replay(g: Gates, fr: dict) -> None:
    flt, ref = fr["faulted"], fr["fault_free"]
    inj = flt["injected"]
    lane = flt["lane"]
    led = flt["ledger"]
    plan = fr["plan"]
    # the tentpole: faults cost bytes and time, never answers — both
    # the downlinked answers AND the raw satellite streams replay
    # identically to the fault-free run
    g.check("faulted replay token-exact vs fault-free",
            fr["token_exact_vs_fault_free"] is True)
    g.check("satellite streams token-exact vs fault-free",
            fr["sat_token_exact_vs_fault_free"] is True)
    # zero silent acceptance: every injected corruption (frame OR
    # spill record) tripped a checksum somewhere — none slipped into
    # an answer or a KV graft
    g.check("corruptions injected", inj["n_corruptions_injected"] > 0,
            f"n={inj['n_corruptions_injected']}")
    g.check("every injected corruption detected",
            flt["n_corruptions_detected"] == inj["n_corruptions_injected"],
            f"{flt['n_corruptions_detected']} vs "
            f"{inj['n_corruptions_injected']}")
    g.check("no silent frame corruption",
            lane["n_silent_corruptions"] == 0,
            f"n={lane['n_silent_corruptions']}")
    g.check("spill corruptions injected and redone from prefill",
            inj["n_spill_corruptions"] > 0
            and flt["n_redo_from_corruption"] > 0,
            f"injected={inj['n_spill_corruptions']} "
            f"redo={flt['n_redo_from_corruption']}")
    # the ARQ path really ran and its cost is metered, both in lane
    # counters and in the energy/byte ledger
    g.check("frames lost and retransmits observed",
            inj["n_frames_lost"] > 0 and lane["n_retransmits"] > 0,
            f"lost={inj['n_frames_lost']} retx={lane['n_retransmits']}")
    g.check("retransmitted bytes metered in ledger",
            lane["bytes_retransmitted"] > 0
            and led.get("bytes_retransmitted", 0) > 0,
            f"lane={lane['bytes_retransmitted']} "
            f"ledger={led.get('bytes_retransmitted', 0)}")
    g.check("lost bytes metered in ledger",
            led.get("bytes_lost", 0) > 0,
            f"ledger={led.get('bytes_lost', 0)}")
    g.check("frame byte ledger conserved",
            flt["frame_ledger_conserved"] is True)
    # goodput degrades by roughly the injected loss, not worse: the
    # retry machinery isn't amplifying failures
    floor = 1.0 - plan["frame_loss_rate"] - plan["frame_corrupt_rate"] - 0.2
    g.check("goodput efficiency bounded below by injected loss",
            floor <= flt["goodput_efficiency"] <= 1.0,
            f"{flt['goodput_efficiency']} vs floor {round(floor, 3)}")
    # crash-safety: the scheduled reboot happened exactly once and the
    # restore left nothing behind
    g.check("crash survived exactly once",
            flt["n_reboots"] == 1 and inj["n_crashes"] == 1,
            f"reboots={flt['n_reboots']} crashes={inj['n_crashes']}")
    g.check("windows truncated by early LOS",
            inj["n_windows_truncated"] > 0,
            f"n={inj['n_windows_truncated']}")
    g.check("every answer delivered despite faults",
            flt["n_undelivered"] == 0 and flt["n_answers"] > 0,
            f"undelivered={flt['n_undelivered']} "
            f"answers={flt['n_answers']}")
    g.check("faulted pool drained post-reboot",
            flt["pool_drained"] is True)
    g.check("faulted spill store empty", flt["spill_store_empty"] is True)
    g.check("fault-free comparator clean",
            ref["pool_drained"] is True and ref["n_reboots"] == 0
            and ref["n_undelivered"] == 0)


def check_speculative(g: Gates, sd: dict) -> None:
    v = sd["verify"]
    plain, spec = v["plain"], v["speculative"]
    # the tentpole: accepted drafts replace decode dispatches with ONE
    # chunked verify pass per slot per tick, greedy token-exact
    g.check("speculative verify replay token-exact vs plain decode",
            v["token_exact"] is True)
    g.check("accepted-token throughput >= plain decode",
            spec["tokens_per_s"] >= plain["tokens_per_s"],
            f"{spec['tokens_per_s']} vs {plain['tokens_per_s']}")
    g.check("speculative run finished in fewer engine ticks",
            spec["clock_steps"] < plain["clock_steps"],
            f"{spec['clock_steps']} vs {plain['clock_steps']}")
    # verification really ran (not a vacuous plain replay)...
    g.check("verify passes observed",
            0 < spec["verify_passes"] < spec["useful_tokens"],
            f"n={spec['verify_passes']}")
    # ...and the self-draft streams (the plain run's own output) are
    # fully accepted — any rejection means verify diverges from decode
    g.check("all self-drafts accepted",
            spec["accepted"] == spec["drafted"] > 0,
            f"{spec['accepted']} vs {spec['drafted']}")
    g.check("no draft streams dropped",
            spec["draft_streams_dropped"] == 0,
            f"n={spec['draft_streams_dropped']}")
    g.check("plain comparator never speculated",
            plain["verify_passes"] == 0 and plain["drafted"] == 0)
    g.check("verify pools drained",
            plain["pool_drained"] is True and spec["pool_drained"] is True)

    c = sd["cascade"]
    raw, spc = c["raw"], c["speculative"]
    g.check("cascade draft escalation token-exact vs raw escalation",
            c["token_exact_vs_raw"] is True)
    g.check("cascade escalation counts match and are nonzero",
            raw["n_escalated"] == spc["n_escalated"] > 0,
            f"{raw['n_escalated']} vs {spc['n_escalated']}")
    # the satellite tentpole: shipping draft ids instead of re-decoding
    # the raw prompt must strictly shrink the per-escalation downlink
    g.check("draft bytes/escalation < raw bytes/escalation",
            spc["bytes_per_escalation"] < raw["bytes_per_escalation"],
            f"{spc['bytes_per_escalation']} vs "
            f"{raw['bytes_per_escalation']}")
    g.check("draft escalation bytes metered in ledger",
            spc["ledger"].get("bytes_draft_escalated", 0) > 0
            and spc["ledger"].get("draft_tokens_shipped", 0) > 0,
            f"bytes={spc['ledger'].get('bytes_draft_escalated', 0)} "
            f"toks={spc['ledger'].get('draft_tokens_shipped', 0)}")
    g.check("ground tier verified drafts",
            spc["spec"].get("verify_passes", 0) > 0
            and spc["spec"].get("accepted", 0) > 0,
            f"passes={spc['spec'].get('verify_passes', 0)} "
            f"accepted={spc['spec'].get('accepted', 0)}")
    # batched verification answers escalations faster than re-decoding
    g.check("ground escalation latency: speculative < raw",
            spc["ground_latency_mean_steps"]
            < raw["ground_latency_mean_steps"],
            f"{spc['ground_latency_mean_steps']} vs "
            f"{raw['ground_latency_mean_steps']}")
    g.check("no undelivered answers in either cascade replay",
            raw["n_undelivered"] == 0 and spc["n_undelivered"] == 0)
    g.check("cascade pools drained",
            raw["pool_drained"] is True and spc["pool_drained"] is True)


def check_constellation(g: Gates, cn: dict) -> None:
    pooled, indep = cn["pooled"], cn["independent_pairs"]
    # the tentpole: pooling K satellites' pass seconds through the
    # value planner + ISL handover beats K uncoordinated pairs on the
    # SAME window sets — and never by cheating on correctness
    g.check("pooled replay token-exact vs solo",
            cn["token_exact_vs_solo"] is True)
    g.check("independent-pairs replay token-exact vs solo",
            cn["independent_token_exact_vs_solo"] is True)
    g.check("pooled goodput >= independent-pairs goodput",
            cn["goodput_ratio"] >= 1.0, f"ratio={cn['goodput_ratio']}")
    # the comparison is at equal energy/byte budget: both fleets stay
    # within the per-satellite bus cap, and the pooled replay downlinks
    # no more answer payload bytes than the comparator (the ISL bytes
    # it spends are metered separately and capped by the same budget)
    g.check("both replays within the per-satellite energy budget",
            pooled["within_energy_budget"] is True
            and indep["within_energy_budget"] is True)
    g.check("pooled downlink payload bytes <= independent pairs'",
            cn["downlink_bytes_ratio"] <= 1.0 + 1e-6,
            f"ratio={cn['downlink_bytes_ratio']}")
    # handovers really happened (not a vacuous win) and paid off over
    # a metered inter-satellite link
    g.check("handovers observed", pooled["n_handovers"] > 0,
            f"n={pooled['n_handovers']}")
    g.check("ISL bytes metered",
            pooled["fleet_totals"].get("bytes_isl", 0) > 0,
            f"bytes={pooled['fleet_totals'].get('bytes_isl', 0)}")
    g.check("independent comparator never hands over",
            indep["n_handovers"] == 0 and indep["handover"] is False)
    g.check("every answer delivered in both replays",
            pooled["n_undelivered"] == 0 and indep["n_undelivered"] == 0,
            f"pooled={pooled['n_undelivered']} "
            f"indep={indep['n_undelivered']}")
    g.check("equal tokens delivered across replays",
            pooled["delivered_tokens"] == indep["delivered_tokens"] > 0,
            f"{pooled['delivered_tokens']} vs {indep['delivered_tokens']}")
    for name, run in (("pooled", pooled), ("independent", indep)):
        g.check(f"{name} pools, spill stores and lanes drained",
                run["pool_drained"] is True
                and run["spill_store_empty"] is True
                and run["lanes_empty"] is True)


def check_sharded(g: Gates, sh: dict) -> None:
    sd, shd = sh["single_device"], sh["sharded"]
    moe = sh["moe"]
    # the tentpole: sharding the engine across the mesh must never
    # change an answer...
    g.check("sharded dense replay token-exact vs single-device",
            sh["token_exact"] is True)
    g.check("sharded MoE replay token-exact vs single-device",
            moe["token_exact"] is True)
    # ...and must not cost throughput at equal batch (parity floor —
    # every bench lane timeshares one core across the forced devices)
    g.check("sharded tokens/s >= parity floor vs single-device",
            sh["throughput_ratio"] >= SHARDED_MIN_RATIO,
            f"ratio={sh['throughput_ratio']} floor={SHARDED_MIN_RATIO}")
    g.check("sharded run uses the paged layout",
            shd["kv_layout"] == "paged")
    # per-device accounting: the KV pool shards only head/latent axes,
    # so per-device bytes times the shard count rebuilds the global
    # pool exactly and the page ledger is identical on every device
    g.check("per-device KV bytes x shards == global KV bytes",
            sh["kv_bytes_conserved"] is True,
            f"{shd['kv_bytes_per_device']} x {shd['n_kv_shards']} "
            f"vs {shd['kv_cache_bytes']}")
    g.check("per-device peak pages == global peak pages",
            sh["peak_pages_match_ledger"] is True,
            f"{shd['peak_pages_in_use_per_device']} "
            f"vs {shd['peak_pages_in_use']}")
    g.check("mesh spans every visible device",
            shd["mesh_devices"] == sh["n_devices"] >= 1,
            f"mesh={shd['mesh_devices']} visible={sh['n_devices']}")
    g.check("single-device comparator is unsharded",
            sd["n_kv_shards"] == 1, f"n={sd['n_kv_shards']}")
    # expert-parallel dispatch really metered per device
    g.check("MoE expert dispatch conserved across devices",
            moe["expert_dispatch_conserved"] is True,
            f"{moe['sharded']['experts_per_device']} x "
            f"{moe['sharded']['n_expert_shards']} "
            f"vs {moe['n_experts']}")
    g.check("MoE expert shards cover the mesh",
            moe["sharded"]["n_expert_shards"] == shd["mesh_devices"],
            f"{moe['sharded']['n_expert_shards']} "
            f"vs {shd['mesh_devices']}")
    g.check("sharded pools drained",
            shd["pool_drained"] is True
            and moe["sharded"]["pool_drained"] is True)
    g.check("single-device pools drained",
            sd["pool_drained"] is True
            and moe["single_device"]["pool_drained"] is True)


def main(argv) -> int:
    path = argv[1] if len(argv) > 1 else "BENCH_serving.json"
    with open(path) as f:
        bench = json.load(f)
    g = Gates()
    check_version(g, bench)
    if g.failures:
        # a stale benchmark may predate gated keys entirely: stop at
        # the version gate with a clear remedy instead of dying in a
        # KeyError mid-report
        got = bench.get("bench_version")
        print(f"\nFAILED: bench_version {got} < GATE_VERSION "
              f"{GATE_VERSION} — rerun the benchmark "
              f"(`PYTHONPATH=src python -m benchmarks.serving_throughput`) "
              f"to refresh {path}")
        return 1
    check_throughput(g, bench)
    check_contact_window(g, bench["contact_window"])
    check_overlap(g, bench["contact_window"]["overlap"])
    check_chunked_prefill(g, bench["chunked_prefill"])
    check_shared_prefix(g, bench["shared_prefix"])
    check_fault_replay(g, bench["fault_replay"])
    check_speculative(g, bench["speculative"])
    check_constellation(g, bench["constellation"])
    check_sharded(g, bench["sharded"])
    print(f"\n{'OK' if not g.failures else 'FAILED'}: "
          f"{g.failures} gate(s) failed ({path}, gate v{GATE_VERSION})")
    return 1 if g.failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
