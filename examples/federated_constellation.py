"""Federated learning across a mini constellation (paper §3.4).

Three satellites hold disjoint data shards (privacy: raw data never
downlinked); each trains locally and uploads weights at its next ground
contact; the cloud aggregates with staleness-discounted FedAvg.

    PYTHONPATH=src python examples/federated_constellation.py
"""
import jax
import jax.numpy as jnp

from repro.config import get_reduced_config
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.models import transformer as T
from repro.training.federated import FedConfig, run_federated


def main():
    cfg = get_reduced_config("smollm-360m")
    fed = FedConfig(n_satellites=3, local_steps=10, rounds=3)
    print(f"federating {cfg.name} across {fed.n_satellites} satellites, "
          f"{fed.rounds} rounds x {fed.local_steps} local steps")

    def make_data(i):
        return iter(TokenStream(TokenStreamConfig(
            vocab_size=cfg.vocab_size, seq_len=64, batch_size=4,
            seed=1000 + i)))

    out = run_federated(cfg, fed, make_data, max_seq=64)
    for r in out["rounds"]:
        w = ", ".join(f"{x:.2f}" for x in r["weights"])
        l = ", ".join(f"{x:.3f}" for x in r["local_losses"])
        print(f"  round {r['round']}: staleness weights [{w}] "
              f"local losses [{l}]")

    # evaluate the aggregated global model on held-out data
    batch = {"tokens": jnp.asarray(
        TokenStream(TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                      batch_size=8, seed=77)).batch(0)["tokens"])}
    loss, _ = T.loss_fn(out["global_params"], cfg, batch)
    print(f"global model held-out loss: {float(loss):.3f}")


if __name__ == "__main__":
    main()
