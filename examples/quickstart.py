"""Quickstart: train a reduced assigned architecture on the synthetic
token stream, checkpoint it, reload, and generate.

    PYTHONPATH=src python examples/quickstart.py [--arch xlstm-1.3b]
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.config import ARCH_IDS, get_reduced_config
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.serving.engine import ServingEngine
from repro.training import optim
from repro.training.loop import init_state, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    print(f"[1/4] training {cfg.name} ({cfg.param_count():,} params)")
    opt_cfg = optim.OptimConfig(lr=2e-3, warmup_steps=5,
                                total_steps=args.steps)
    stream = TokenStream(TokenStreamConfig(vocab_size=cfg.vocab_size,
                                           seq_len=128, batch_size=8))
    state = init_state(cfg, opt_cfg, max_seq=128)
    state = train(cfg, state, iter(stream), opt_cfg, steps=args.steps,
                  log_every=10,
                  callback=lambda r: print(
                      f"    step {r['step']:3d} loss {r['loss']:.3f}"))

    with tempfile.TemporaryDirectory() as d:
        path = f"{d}/model.ckpt"
        nbytes = save_checkpoint(path, state.params, {"arch": cfg.name})
        print(f"[2/4] checkpointed {nbytes/1e6:.1f} MB -> {path}")
        like = jax.eval_shape(lambda: state.params)
        params, meta = load_checkpoint(path, like)
        print(f"[3/4] reloaded checkpoint for {meta['arch']}")

    eng = ServingEngine(cfg, params, max_seq=160)
    prompt = stream.batch(0)["tokens"][:2, :16]
    res = eng.generate(prompt, max_new=12)
    print("[4/4] generated continuations:")
    for row in res.tokens:
        print("   ", row.tolist())


if __name__ == "__main__":
    main()
