"""END-TO-END DRIVER — the paper's case study as a running system.

A Tiansuan-style deployment: the cloud-native control plane registers a
satellite (Baoyun) and a ground station, deploys the onboard/ground
tiers via manifests, then serves batched EO requests through the full
collaborative pipeline:

    frames -> onboard tile split -> cloud/redundancy filter
           -> onboard tier inference -> confidence gate
           -> {results downlink | raw escalation over the contact-gated
               message bus} -> ground tier -> merged predictions

and prints the paper's headline metrics from the ledger (accuracy vs
in-orbit-only, downlinked bytes vs bent-pipe, energy shares).

    PYTHONPATH=src python examples/collaborative_inference.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import classifier as CL
from repro.core.cascade import CascadeConfig, CollaborativeEngine
from repro.core.energy import EnergyModel
from repro.core.filtering import filter_tiles
from repro.core.gating import ConfidenceGate
from repro.core.link import ContactSchedule
from repro.core.tiling import split_batch
from repro.data import eo
from repro.orchestration import (AppManifest, Deployer, MessageBus,
                                 NodeSpec, Registry)


def main():
    # ---- control plane ----------------------------------------------------
    print("[1/5] registering nodes (KubeEdge-style control plane)")
    reg = Registry()
    reg.register(NodeSpec("baoyun", "satellite",
                          contacts=ContactSchedule(seed=4)))
    reg.register(NodeSpec("ground-0", "ground"))
    bus = MessageBus(reg)

    print("[2/5] training tier models (YOLOv3-tiny / YOLOv3 analogues)")
    # match the captured scene's clear-tile distribution (V1 defaults)
    tcfg = eo.EOConfig(cloud_fraction=0.0, dup_fraction=0.0, contrast=0.55,
                       noise=0.24, seed=41)
    tr_t, tr_l, _ = eo.make_tiles(2000, tcfg)
    onboard_p, _ = CL.train_classifier(CL.ONBOARD, tr_t, tr_l, steps=500)
    ground_p, _ = CL.train_classifier(CL.GROUND, tr_t, tr_l, steps=700)

    dep = Deployer(reg)
    dep.apply(AppManifest("onboard-infer", "baoyun",
                          factory=lambda: (CL.ONBOARD, onboard_p)))
    dep.apply(AppManifest("ground-infer", "ground-0",
                          factory=lambda: (CL.GROUND, ground_p)))

    # ---- a day in orbit: frames arrive in batches ---------------------------
    print("[3/5] capturing frames, splitting, filtering onboard")
    scene = eo.EOConfig(cloud_fraction=0.86, dup_fraction=0.30,
                        contrast=0.55, noise=0.24, seed=1)   # cloudy scene
    frames, labels, _ = eo.make_tiles(800, scene)
    tiles = np.asarray(split_batch(jnp.asarray(frames), 32))
    # labels carry over 1:1 because frames are already tile-sized here
    keep, fstats = filter_tiles(jnp.asarray(frames))
    keep = np.asarray(keep)
    survivors, slabels = frames[keep], labels[keep]
    print(f"    filter rate: {float(fstats['filter_rate']):.2f} "
          f"({len(survivors)}/{len(frames)} tiles survive)")

    # ---- collaborative inference -------------------------------------------
    print("[4/5] onboard inference + confidence gate + escalation")
    from repro.core.gating import calibrate_threshold
    cfgs, onboard_params = dep.worker("onboard-infer")
    gcfg, ground_params = dep.worker("ground-infer")
    onboard_fn = lambda b: CL.apply_classifier(onboard_params, cfgs,
                                               jnp.asarray(b))
    probe = np.asarray(ConfidenceGate("max_prob", 1.1).decide(
        jnp.asarray(onboard_fn(survivors)))["confidence"])
    thr = calibrate_threshold(probe, np.ones_like(probe, bool), 0.45)
    engine = CollaborativeEngine(
        onboard_fn,
        lambda b: CL.apply_classifier(ground_params, gcfg, jnp.asarray(b)),
        CascadeConfig(gate=ConfidenceGate("max_prob", thr),
                      item_dtype_bytes=4))
    res = engine.run(survivors, item_shape=survivors.shape[1:])
    inorbit = engine.run(survivors, item_shape=survivors.shape[1:],
                         ground_available=False)

    # escalated payloads ride the contact-gated bus
    n_esc = int(res.escalated.sum())
    dt = bus.send("baoyun", "ground-0", "escalations", None,
                  nbytes=int(res.ledger.get("bytes_raw_escalated")), t=0.0)
    bus.advance(dt or 0.0)

    # ---- report -------------------------------------------------------------
    print("[5/5] results")
    valid = slabels >= 0
    acc_c = float(np.mean(res.predictions[valid] == slabels[valid]))
    acc_o = float(np.mean(inorbit.predictions[valid] == slabels[valid]))
    s = res.ledger.summary()
    em = EnergyModel()
    print(f"    in-orbit accuracy:        {acc_o:.3f} "
          f"({int(valid.sum())} labeled survivors)")
    print(f"    collaborative accuracy:   {acc_c:.3f} "
          f"(+{(acc_c-acc_o)/max(acc_o,1e-9)*100:.0f}% relative; paper "
          f"reports ~+50% — see benchmarks/fig7 for the calibrated run)")
    print(f"    escalated:                {n_esc}/{len(survivors)} items, "
          f"delivered at t={dt:.0f}s via contact window")
    print(f"    downlinked bytes:         {int(s['bytes_downlinked']):,} vs "
          f"bent-pipe {int(frames.nbytes):,}")
    print(f"    total data reduction:     "
          f"{1 - s['bytes_downlinked']/frames.nbytes:.2f} (paper: 0.90)")
    print(f"    compute share of energy:  "
          f"{em.compute_share_of_total():.2f} (paper: 0.17)")


if __name__ == "__main__":
    main()
